//! The experiment coordinator: runs workloads on simulated systems,
//! collects results, and drives the figure/table sweeps of the paper's
//! evaluation (§VII-§IX).

pub mod automap;
pub mod experiments;
pub mod faults;
pub mod reliability;
pub mod server;
pub mod serving;

use crate::config::{SystemConfig, SystemKind};
use crate::energy::{self, EnergyBreakdown};
use crate::sim::{Machine, RunError, TileDriftSpec, TileFaultModel};
use crate::stats::{RoiTimes, RunStats};
use crate::workload::Workload;

/// One (workload, system) simulation outcome.
#[derive(Clone, Debug)]
pub struct CaseResult {
    pub label: String,
    pub system: SystemKind,
    pub inferences: u32,
    pub time_s: f64,
    pub time_per_inference_s: f64,
    pub llc_mpki: f64,
    pub energy: EnergyBreakdown,
    pub total_insts: u64,
    pub dram_accesses: u64,
    pub aimc_processes: u64,
    pub roi: RoiTimes,
    pub per_core_ipc: Vec<f64>,
    pub per_core_idle: Vec<f64>,
    pub per_core_wfm: Vec<f64>,
}

impl CaseResult {
    pub fn energy_per_inference_j(&self) -> f64 {
        self.energy.total_j() / self.inferences.max(1) as f64
    }
}

/// Knobs of one simulation run — the single options struct every
/// [`run_workload`] caller passes, replacing both the old
/// `run_workload` / `run_workload_with(faults)` pair and the scattered
/// `Machine::set_*` calls drivers used to make by hand. `Default`
/// reproduces the knob-free run of previous releases bit-identically:
/// no faults, fast-forward on (nested per the process-wide default),
/// batched stream modeling on.
#[derive(Clone, Debug)]
pub struct RunOptions {
    /// Per-tile fault models injected before the run (the `alpine
    /// faults` scenario driver). Tile indices must be valid for the
    /// workload's machine spec; empty is the fault-free path.
    pub faults: Vec<(usize, TileFaultModel)>,
    /// Per-tile conductance-drift models (`Machine::set_tile_drift`).
    /// Accuracy-only: attaching specs — active or inactive — leaves
    /// `RunStats` bit-identical and keeps fast-forward enabled
    /// (pinned by `tests/faults.rs` / `tests/fastforward.rs`).
    pub drift: Vec<(usize, TileDriftSpec)>,
    /// Replay-identical fast-forward over detected steady-state periods
    /// (`Machine::set_fast_forward`).
    pub fast_forward: bool,
    /// `Some(_)` overrides the process-wide nested fast-forward default
    /// for this run (`Machine::set_nested_fast_forward`); `None` keeps
    /// it.
    pub nested_ff: Option<bool>,
    /// Charge MemStream lines in overlapped batches
    /// (`Machine::set_batched_streams`).
    pub batched_streams: bool,
    /// Worker threads for drivers that simulate many workloads under
    /// one options value (e.g. the automap validation fan-out); `None`
    /// keeps each driver's own default. A single `run_workload` call
    /// ignores it.
    pub jobs: Option<usize>,
}

impl Default for RunOptions {
    fn default() -> RunOptions {
        RunOptions {
            faults: Vec::new(),
            drift: Vec::new(),
            fast_forward: true,
            nested_ff: None,
            batched_streams: true,
            jobs: None,
        }
    }
}

impl RunOptions {
    /// `Default` plus per-tile fault models.
    pub fn with_faults(faults: Vec<(usize, TileFaultModel)>) -> RunOptions {
        RunOptions { faults, ..RunOptions::default() }
    }

    /// `Default` plus per-tile drift models.
    pub fn with_drift(drift: Vec<(usize, TileDriftSpec)>) -> RunOptions {
        RunOptions { drift, ..RunOptions::default() }
    }
}

/// Simulate one workload on one system configuration under the given
/// [`RunOptions`].
///
/// The workload is consumed in place: spec and traces move straight
/// into the machine (the spec clone + trace copy this used to make cost
/// a full trace duplication per case on the multi-megaop CNN sweeps).
/// A machine-level failure (deadlock, injected tile fault) surfaces as
/// a typed [`RunError`] instead of aborting the sweep.
pub fn run_workload(
    kind: SystemKind,
    workload: Workload,
    opts: &RunOptions,
) -> Result<CaseResult, RunError> {
    let Workload { label, traces, spec, inferences } = workload;
    let cfg = SystemConfig::for_kind(kind);
    let mut machine = Machine::new(cfg.clone(), spec);
    machine.set_fast_forward(opts.fast_forward);
    if let Some(nested) = opts.nested_ff {
        machine.set_nested_fast_forward(nested);
    }
    machine.set_batched_streams(opts.batched_streams);
    for &(tile, model) in &opts.faults {
        machine.set_tile_fault(tile, model);
    }
    for &(tile, spec) in &opts.drift {
        machine.set_tile_drift(tile, spec);
    }
    let stats: RunStats = machine.run(traces)?;
    let energy = energy::compute(&cfg, &stats);
    Ok(CaseResult {
        label,
        system: kind,
        inferences,
        time_s: stats.roi_time_s(),
        time_per_inference_s: stats.roi_time_s() / inferences.max(1) as f64,
        llc_mpki: stats.llc_mpki(),
        energy,
        total_insts: stats.total_insts(),
        dram_accesses: stats.dram_accesses,
        aimc_processes: stats.aimc.processes,
        roi: stats.roi.clone(),
        per_core_ipc: stats.cores.iter().map(|c| c.ipc()).collect(),
        per_core_idle: stats.cores.iter().map(|c| c.idle_fraction()).collect(),
        per_core_wfm: stats
            .cores
            .iter()
            .map(|c| c.wfm_cycles as f64 / c.total_cycles().max(1) as f64)
            .collect(),
    })
}

/// Speedup of `b` relative to `a` (a.time / b.time).
pub fn speedup(a: &CaseResult, b: &CaseResult) -> f64 {
    a.time_s / b.time_s
}

/// Energy improvement of `b` relative to `a`.
pub fn energy_gain(a: &CaseResult, b: &CaseResult) -> f64 {
    a.energy.total_j() / b.energy.total_j()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::mlp::{self, MlpCase};

    #[test]
    fn run_workload_produces_sane_result() {
        let cfg = SystemConfig::high_power();
        let w = mlp::generate(MlpCase::Analog { case: 1 }, &cfg, 2).unwrap();
        let r = run_workload(SystemKind::HighPower, w, &RunOptions::default()).unwrap();
        assert!(r.time_s > 0.0);
        assert!(r.energy.total_j() > 0.0);
        assert_eq!(r.aimc_processes, 4); // 2 layers x 2 inferences
        assert!(r.time_per_inference_s < r.time_s);
    }

    #[test]
    fn speedup_and_energy_gain_definitions() {
        let cfg = SystemConfig::high_power();
        let dig = run_workload(
            SystemKind::HighPower,
            mlp::generate(MlpCase::Digital { cores: 1 }, &cfg, 2).unwrap(),
            &RunOptions::default(),
        )
        .unwrap();
        let ana = run_workload(
            SystemKind::HighPower,
            mlp::generate(MlpCase::Analog { case: 1 }, &cfg, 2).unwrap(),
            &RunOptions::default(),
        )
        .unwrap();
        let s = speedup(&dig, &ana);
        assert!(s > 1.0, "analog should win: {s}");
        assert!(energy_gain(&dig, &ana) > 1.0);
    }
}
