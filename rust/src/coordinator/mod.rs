//! The experiment coordinator: runs workloads on simulated systems,
//! collects results, and drives the figure/table sweeps of the paper's
//! evaluation (§VII-§IX).

pub mod automap;
pub mod experiments;
pub mod faults;
pub mod server;

use crate::config::{SystemConfig, SystemKind};
use crate::energy::{self, EnergyBreakdown};
use crate::sim::{Machine, RunError, TileFaultModel};
use crate::stats::{RoiTimes, RunStats};
use crate::workload::Workload;

/// One (workload, system) simulation outcome.
#[derive(Clone, Debug)]
pub struct CaseResult {
    pub label: String,
    pub system: SystemKind,
    pub inferences: u32,
    pub time_s: f64,
    pub time_per_inference_s: f64,
    pub llc_mpki: f64,
    pub energy: EnergyBreakdown,
    pub total_insts: u64,
    pub dram_accesses: u64,
    pub aimc_processes: u64,
    pub roi: RoiTimes,
    pub per_core_ipc: Vec<f64>,
    pub per_core_idle: Vec<f64>,
    pub per_core_wfm: Vec<f64>,
}

impl CaseResult {
    pub fn energy_per_inference_j(&self) -> f64 {
        self.energy.total_j() / self.inferences.max(1) as f64
    }
}

/// Simulate one workload on one system configuration.
///
/// The workload is consumed in place: spec and traces move straight
/// into the machine (the spec clone + trace copy this used to make cost
/// a full trace duplication per case on the multi-megaop CNN sweeps).
/// A machine-level failure (deadlock, injected tile fault) surfaces as
/// a typed [`RunError`] instead of aborting the sweep.
pub fn run_workload(kind: SystemKind, workload: Workload) -> Result<CaseResult, RunError> {
    run_workload_with(kind, workload, &[])
}

/// [`run_workload`] with per-tile fault models injected before the run
/// (the `alpine faults` scenario driver). Tile indices must be valid
/// for the workload's machine spec. An empty slice is the fault-free
/// path and stays bit-identical to [`run_workload`].
pub fn run_workload_with(
    kind: SystemKind,
    workload: Workload,
    faults: &[(usize, TileFaultModel)],
) -> Result<CaseResult, RunError> {
    let Workload { label, traces, spec, inferences } = workload;
    let cfg = SystemConfig::for_kind(kind);
    let mut machine = Machine::new(cfg.clone(), spec);
    for &(tile, model) in faults {
        machine.set_tile_fault(tile, model);
    }
    let stats: RunStats = machine.run(traces)?;
    let energy = energy::compute(&cfg, &stats);
    Ok(CaseResult {
        label,
        system: kind,
        inferences,
        time_s: stats.roi_time_s(),
        time_per_inference_s: stats.roi_time_s() / inferences.max(1) as f64,
        llc_mpki: stats.llc_mpki(),
        energy,
        total_insts: stats.total_insts(),
        dram_accesses: stats.dram_accesses,
        aimc_processes: stats.aimc.processes,
        roi: stats.roi.clone(),
        per_core_ipc: stats.cores.iter().map(|c| c.ipc()).collect(),
        per_core_idle: stats.cores.iter().map(|c| c.idle_fraction()).collect(),
        per_core_wfm: stats
            .cores
            .iter()
            .map(|c| c.wfm_cycles as f64 / c.total_cycles().max(1) as f64)
            .collect(),
    })
}

/// Speedup of `b` relative to `a` (a.time / b.time).
pub fn speedup(a: &CaseResult, b: &CaseResult) -> f64 {
    a.time_s / b.time_s
}

/// Energy improvement of `b` relative to `a`.
pub fn energy_gain(a: &CaseResult, b: &CaseResult) -> f64 {
    a.energy.total_j() / b.energy.total_j()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::mlp::{self, MlpCase};

    #[test]
    fn run_workload_produces_sane_result() {
        let cfg = SystemConfig::high_power();
        let w = mlp::generate(MlpCase::Analog { case: 1 }, &cfg, 2).unwrap();
        let r = run_workload(SystemKind::HighPower, w).unwrap();
        assert!(r.time_s > 0.0);
        assert!(r.energy.total_j() > 0.0);
        assert_eq!(r.aimc_processes, 4); // 2 layers x 2 inferences
        assert!(r.time_per_inference_s < r.time_s);
    }

    #[test]
    fn speedup_and_energy_gain_definitions() {
        let cfg = SystemConfig::high_power();
        let dig = run_workload(
            SystemKind::HighPower,
            mlp::generate(MlpCase::Digital { cores: 1 }, &cfg, 2).unwrap(),
        )
        .unwrap();
        let ana = run_workload(
            SystemKind::HighPower,
            mlp::generate(MlpCase::Analog { case: 1 }, &cfg, 2).unwrap(),
        )
        .unwrap();
        let s = speedup(&dig, &ana);
        assert!(s > 1.0, "analog should win: {s}");
        assert!(energy_gain(&dig, &ana) > 1.0);
    }
}
