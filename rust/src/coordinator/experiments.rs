//! The paper's evaluation sweeps — one function per figure/table.
//!
//! Every sweep is a flat list of independent [`SweepCase`] descriptors
//! (workload x system, as pure data) dispatched across the machine's
//! cores by `util::parallel`; each worker generates its workload locally
//! and simulates it on a self-contained `Machine`. Rows come back in the
//! exact serial order and are bit-identical to a serial run (see the
//! determinism test at the bottom). `report` renders the rows as the
//! tables/series underlying the paper's bar charts.

use crate::config::{SystemConfig, SystemKind};
use crate::nn::CnnVariant;
use crate::sim::RunError;
use crate::util::parallel;
use crate::workload::cnn::{self, CnnCase};
use crate::workload::lstm::{self, LstmCase};
use crate::workload::mlp::{self, CustomMlpMapping, MlpCase, MlpShape};
use crate::workload::transformer::{self, TransformerCase, TransformerShape};

use super::{run_workload, CaseResult, RunOptions};

/// Default inference counts (§VI.C: 10 for MLP/LSTM, 3 for CNN; the
/// transformer token steps match the MLP count).
pub const MLP_INFERENCES: u32 = 10;
pub const LSTM_INFERENCES: u32 = 10;
pub const CNN_INFERENCES: u32 = 3;
pub const TRANSFORMER_INFERENCES: u32 = 10;

pub const MLP_CASES: [MlpCase; 7] = [
    MlpCase::Digital { cores: 1 },
    MlpCase::Digital { cores: 2 },
    MlpCase::Digital { cores: 4 },
    MlpCase::Analog { case: 1 },
    MlpCase::Analog { case: 2 },
    MlpCase::Analog { case: 3 },
    MlpCase::Analog { case: 4 },
];

pub const LSTM_CASES: [LstmCase; 7] = [
    LstmCase::Digital { cores: 1 },
    LstmCase::Digital { cores: 2 },
    LstmCase::Digital { cores: 5 },
    LstmCase::Analog { case: 1 },
    LstmCase::Analog { case: 2 },
    LstmCase::Analog { case: 3 },
    LstmCase::Analog { case: 4 },
];

pub const LSTM_SIZES: [u64; 3] = [256, 512, 750];

/// One independent case of a figure/table sweep, as plain data so the
/// worker pool can generate + simulate it without sharing any state.
#[derive(Clone, Copy, Debug)]
pub enum SweepCase {
    Mlp { kind: SystemKind, case: MlpCase },
    Lstm { kind: SystemKind, case: LstmCase, n_h: u64 },
    Cnn { kind: SystemKind, case: CnnCase, variant: CnnVariant },
    /// A custom-shape MLP under one of the compiler-backed mappings
    /// (validate with `mlp::generate_custom` before enqueueing).
    CustomMlp { kind: SystemKind, shape: MlpShape, mapping: CustomMlpMapping },
    /// A transformer encoder under one of the hand-written case-table
    /// mappings (the automap search goes through `coordinator::automap`).
    Transformer { kind: SystemKind, shape: TransformerShape, case: TransformerCase },
}

/// Generate and simulate one sweep case (runs inside a worker). Sweep
/// case lists are built from the fixed figure tables or pre-validated
/// CLI input, so an unsupported case here is a caller bug; a machine
/// failure (deadlock, injected tile fault) is a typed `RunError`.
pub fn run_case(case: SweepCase, n_inf: u32) -> Result<CaseResult, RunError> {
    let ro = RunOptions::default();
    match case {
        SweepCase::Mlp { kind, case } => {
            let cfg = SystemConfig::for_kind(kind);
            run_workload(kind, mlp::generate(case, &cfg, n_inf).expect("sweep case table is valid"), &ro)
        }
        SweepCase::Lstm { kind, case, n_h } => {
            let cfg = SystemConfig::for_kind(kind);
            run_workload(kind, lstm::generate(case, n_h, &cfg, n_inf).expect("sweep case table is valid"), &ro)
        }
        SweepCase::Cnn { kind, case, variant } => {
            let cfg = SystemConfig::for_kind(kind);
            run_workload(kind, cnn::generate(case, variant, &cfg, n_inf).expect("sweep case table is valid"), &ro)
        }
        SweepCase::CustomMlp { kind, shape, mapping } => run_workload(
            kind,
            mlp::generate_custom(shape, mapping, n_inf).expect("custom sweep case was pre-validated"),
            &ro,
        ),
        SweepCase::Transformer { kind, shape, case } => run_workload(
            kind,
            transformer::generate(shape, case, n_inf).expect("transformer sweep case was pre-validated"),
            &ro,
        ),
    }
}

/// Run a sweep on `jobs` workers. Rows are returned in `cases` order;
/// with `jobs == 1` this is exactly the serial loop the figures used to
/// run (and any `jobs` produces bit-identical rows — each case is an
/// isolated deterministic simulation). The first failing case (in
/// `cases` order, independent of worker scheduling) aborts the sweep.
pub fn run_cases(
    cases: &[SweepCase],
    n_inf: u32,
    jobs: usize,
) -> Result<Vec<CaseResult>, RunError> {
    parallel::parallel_map(cases.to_vec(), jobs, |c| run_case(c, n_inf))
        .into_iter()
        .collect()
}

fn run_sweep(cases: Vec<SweepCase>, n_inf: u32) -> Result<Vec<CaseResult>, RunError> {
    run_cases(&cases, n_inf, parallel::jobs())
}

/// Fig. 7 case list: all MLP cases on both systems.
pub fn fig7_cases() -> Vec<SweepCase> {
    let mut out = Vec::new();
    for kind in SystemKind::ALL {
        for case in MLP_CASES {
            out.push(SweepCase::Mlp { kind, case });
        }
    }
    out
}

/// Fig. 7: all MLP cases on both systems.
pub fn fig7_mlp(n_inf: u32) -> Result<Vec<CaseResult>, RunError> {
    run_sweep(fig7_cases(), n_inf)
}

/// Fig. 8 case list: MLP reference + analog cases 1/3/4 on both systems
/// (case 2's distribution matches case 1, as the paper notes).
pub fn fig8_cases() -> Vec<SweepCase> {
    let mut out = Vec::new();
    for kind in SystemKind::ALL {
        for case in [
            MlpCase::Digital { cores: 1 },
            MlpCase::Analog { case: 1 },
            MlpCase::Analog { case: 3 },
            MlpCase::Analog { case: 4 },
        ] {
            out.push(SweepCase::Mlp { kind, case });
        }
    }
    out
}

/// Fig. 8: sub-ROI breakdown for the MLP reference + analog cases 1/3/4.
pub fn fig8_mlp_breakdown(n_inf: u32) -> Result<Vec<CaseResult>, RunError> {
    run_sweep(fig8_cases(), n_inf)
}

/// §VII.B case list: loose vs tight vs digital single-core.
pub fn loose_vs_tight_cases() -> Vec<SweepCase> {
    let mut out = Vec::new();
    for kind in SystemKind::ALL {
        for case in [
            MlpCase::Digital { cores: 1 },
            MlpCase::Analog { case: 1 },
            MlpCase::AnalogLoose,
        ] {
            out.push(SweepCase::Mlp { kind, case });
        }
    }
    out
}

/// §VII.B: loosely-coupled vs tightly-coupled vs digital single-core.
pub fn loose_vs_tight(n_inf: u32) -> Result<Vec<CaseResult>, RunError> {
    run_sweep(loose_vs_tight_cases(), n_inf)
}

/// Fig. 10 case list: all LSTM cases x sizes x systems (42 runs).
pub fn fig10_cases() -> Vec<SweepCase> {
    let mut out = Vec::new();
    for kind in SystemKind::ALL {
        for n_h in LSTM_SIZES {
            for case in LSTM_CASES {
                out.push(SweepCase::Lstm { kind, case, n_h });
            }
        }
    }
    out
}

/// Fig. 10: all LSTM cases x sizes x systems.
pub fn fig10_lstm(n_inf: u32) -> Result<Vec<CaseResult>, RunError> {
    run_sweep(fig10_cases(), n_inf)
}

/// Fig. 11 case list: LSTM analog sub-ROI breakdown (high-power).
pub fn fig11_cases() -> Vec<SweepCase> {
    let mut out = Vec::new();
    for n_h in LSTM_SIZES {
        for case in [
            LstmCase::Analog { case: 1 },
            LstmCase::Analog { case: 2 },
            LstmCase::Analog { case: 3 },
            LstmCase::Analog { case: 4 },
        ] {
            out.push(SweepCase::Lstm { kind: SystemKind::HighPower, case, n_h });
        }
    }
    out
}

/// Fig. 11: LSTM analog sub-ROI breakdown (high-power, all sizes).
pub fn fig11_lstm_breakdown(n_inf: u32) -> Result<Vec<CaseResult>, RunError> {
    run_sweep(fig11_cases(), n_inf)
}

/// Fig. 13 case list: CNN F/M/S, digital vs analog, both systems.
pub fn fig13_cases() -> Vec<SweepCase> {
    let mut out = Vec::new();
    for kind in SystemKind::ALL {
        for variant in CnnVariant::ALL {
            for case in [CnnCase::Digital, CnnCase::Analog] {
                out.push(SweepCase::Cnn { kind, case, variant });
            }
        }
    }
    out
}

/// Fig. 13: CNN F/M/S, digital vs analog, both systems.
pub fn fig13_cnn(n_inf: u32) -> Result<Vec<CaseResult>, RunError> {
    run_sweep(fig13_cases(), n_inf)
}

/// Fig. 14 case list: CNN-S utilization pair on the high-power system.
pub fn fig14_cases() -> Vec<SweepCase> {
    [CnnCase::Digital, CnnCase::Analog]
        .into_iter()
        .map(|case| SweepCase::Cnn {
            kind: SystemKind::HighPower,
            case,
            variant: CnnVariant::Slow,
        })
        .collect()
}

/// Fig. 14: CNN-S per-core utilization on the high-power system.
pub fn fig14_cnn_utilization(n_inf: u32) -> Result<Vec<CaseResult>, RunError> {
    run_sweep(fig14_cases(), n_inf)
}

/// Default mapping set for a custom-shape MLP sweep: digital 1-core,
/// digital per-layer pipeline, one packed crossbar, and an L-stage
/// pipelined analog configuration (for 3+ layer shapes this is the
/// ">= 3-stage pipelined analog" configuration no hand-written
/// generator could express).
pub fn custom_mlp_mappings(shape: MlpShape) -> Vec<CustomMlpMapping> {
    let layers = shape.layers();
    let mut out = vec![
        CustomMlpMapping::Digital { cores: 1 },
        CustomMlpMapping::Analog { tiles: 1, pipeline: false },
    ];
    if layers > 1 {
        out.push(CustomMlpMapping::Digital { cores: layers });
        out.push(CustomMlpMapping::Analog { tiles: layers, pipeline: true });
    }
    out
}

/// Case list of a custom-shape MLP sweep: every default mapping on both
/// systems.
pub fn custom_mlp_cases(shape: MlpShape) -> Vec<SweepCase> {
    let mut out = Vec::new();
    for kind in SystemKind::ALL {
        for mapping in custom_mlp_mappings(shape) {
            out.push(SweepCase::CustomMlp { kind, shape, mapping });
        }
    }
    out
}

/// Sweep a custom-shape MLP across the default mappings and both systems.
pub fn custom_mlp(shape: MlpShape, n_inf: u32) -> Result<Vec<CaseResult>, RunError> {
    run_sweep(custom_mlp_cases(shape), n_inf)
}

/// Case list of a transformer sweep: both hand-written mappings on both
/// systems.
pub fn transformer_cases(shape: TransformerShape) -> Vec<SweepCase> {
    let mut out = Vec::new();
    for kind in SystemKind::ALL {
        for case in [TransformerCase::Digital, TransformerCase::Analog] {
            out.push(SweepCase::Transformer { kind, shape, case });
        }
    }
    out
}

/// Sweep the transformer hand mappings across both systems.
pub fn transformer_sweep(shape: TransformerShape, n_inf: u32) -> Result<Vec<CaseResult>, RunError> {
    run_sweep(transformer_cases(shape), n_inf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_row_count() {
        let rows = fig7_mlp(1).unwrap();
        assert_eq!(rows.len(), 2 * 7);
    }

    #[test]
    fn loose_tight_ordering_holds() {
        // §VII.B: tight > loose > digital.
        let rows = loose_vs_tight(2).unwrap();
        let hp: Vec<&CaseResult> = rows
            .iter()
            .filter(|r| r.system == SystemKind::HighPower)
            .collect();
        let dig = hp.iter().find(|r| r.label.contains("DIG")).unwrap();
        let tight = hp.iter().find(|r| r.label.contains("case1")).unwrap();
        let loose = hp.iter().find(|r| r.label.contains("loose")).unwrap();
        assert!(tight.time_s < loose.time_s, "tight faster than loose");
        assert!(loose.time_s < dig.time_s, "loose faster than digital");
    }

    #[test]
    fn all_case_lists_nonempty_and_sized() {
        assert_eq!(fig7_cases().len(), 14);
        assert_eq!(fig8_cases().len(), 8);
        assert_eq!(loose_vs_tight_cases().len(), 6);
        assert_eq!(fig10_cases().len(), 42);
        assert_eq!(fig11_cases().len(), 12);
        assert_eq!(fig13_cases().len(), 12);
        assert_eq!(fig14_cases().len(), 2);
        let shape = MlpShape::parse("784x512x512x10").unwrap();
        assert_eq!(custom_mlp_cases(shape).len(), 8);
        let t = TransformerShape::new(64, 2, 16, 1, 128).unwrap();
        assert_eq!(transformer_cases(t).len(), 4);
    }

    /// Acceptance: the transformer encoder — a workload class the paper
    /// never ran — sweeps end-to-end through the parallel engine, and
    /// the packed analog mapping beats the digital reference. (At tiny
    /// dims the fp32<->int8 cast cost erodes the analog win, so this
    /// asserts at d_model = 128.)
    #[test]
    fn transformer_sweep_runs_end_to_end() {
        let shape = TransformerShape::new(128, 4, 32, 1, 256).unwrap();
        let rows = run_cases(&transformer_cases(shape), 2, 2).unwrap();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.time_s > 0.0, "{}", r.label);
            assert!(r.energy.total_j() > 0.0, "{}", r.label);
        }
        let hp: Vec<&CaseResult> =
            rows.iter().filter(|r| r.system == SystemKind::HighPower).collect();
        let dig = hp.iter().find(|r| r.label.ends_with("DIG-1core")).unwrap();
        let ana = hp.iter().find(|r| r.label.ends_with("ANA-packed")).unwrap();
        assert!(ana.time_s < dig.time_s, "analog {} vs digital {}", ana.time_s, dig.time_s);
    }

    /// Acceptance: a custom-shape MLP and a 3-stage pipelined analog
    /// mapping — neither expressible by the legacy generators — run end
    /// to end through the (parallel) sweep engine.
    #[test]
    fn custom_mlp_sweep_runs_end_to_end() {
        let shape = MlpShape::parse("784x512x512x10").unwrap();
        let rows = run_cases(&custom_mlp_cases(shape), 2, 2).unwrap();
        assert_eq!(rows.len(), 8);
        for r in &rows {
            assert!(r.time_s > 0.0, "{}", r.label);
            assert!(r.energy.total_j() > 0.0, "{}", r.label);
        }
        let pipe = rows.iter().find(|r| r.label.contains("ANA-pipe3")).expect("3-stage pipeline row");
        assert!(pipe.label.contains("784x512x512x10"));
        assert!(rows.iter().any(|r| r.label.contains("DIG-pipe3")));
    }

    /// The acceptance-criterion determinism check: rows from the parallel
    /// runner must be byte-for-byte identical to a forced serial run —
    /// labels, times, energy, and every per-core statistic.
    #[test]
    fn fig7_parallel_rows_identical_to_serial() {
        let cases = fig7_cases();
        let serial = run_cases(&cases, 1, 1).unwrap();
        let parallel = run_cases(&cases, 1, 4).unwrap();
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.system, b.system);
            assert_eq!(a.inferences, b.inferences);
            assert_eq!(a.time_s.to_bits(), b.time_s.to_bits(), "{}", a.label);
            assert_eq!(
                a.time_per_inference_s.to_bits(),
                b.time_per_inference_s.to_bits()
            );
            assert_eq!(a.llc_mpki.to_bits(), b.llc_mpki.to_bits());
            assert_eq!(
                a.energy.total_j().to_bits(),
                b.energy.total_j().to_bits(),
                "{}",
                a.label
            );
            assert_eq!(a.total_insts, b.total_insts);
            assert_eq!(a.dram_accesses, b.dram_accesses);
            assert_eq!(a.aimc_processes, b.aimc_processes);
            assert_eq!(a.per_core_ipc.len(), b.per_core_ipc.len());
            for (x, y) in a.per_core_ipc.iter().zip(&b.per_core_ipc) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (x, y) in a.per_core_idle.iter().zip(&b.per_core_idle) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (x, y) in a.per_core_wfm.iter().zip(&b.per_core_wfm) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for kind in crate::stats::RoiKind::ALL {
                assert_eq!(a.roi.get(kind), b.roi.get(kind));
            }
        }
    }
}
