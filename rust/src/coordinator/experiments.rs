//! The paper's evaluation sweeps — one function per figure/table.
//! Each returns the raw `CaseResult` rows; `report` renders them as the
//! tables/series underlying the paper's bar charts.

use crate::config::{SystemConfig, SystemKind};
use crate::nn::CnnVariant;
use crate::workload::cnn::{self, CnnCase};
use crate::workload::lstm::{self, LstmCase};
use crate::workload::mlp::{self, MlpCase};

use super::{run_workload, CaseResult};

/// Default inference counts (§VI.C: 10 for MLP/LSTM, 3 for CNN).
pub const MLP_INFERENCES: u32 = 10;
pub const LSTM_INFERENCES: u32 = 10;
pub const CNN_INFERENCES: u32 = 3;

pub const MLP_CASES: [MlpCase; 7] = [
    MlpCase::Digital { cores: 1 },
    MlpCase::Digital { cores: 2 },
    MlpCase::Digital { cores: 4 },
    MlpCase::Analog { case: 1 },
    MlpCase::Analog { case: 2 },
    MlpCase::Analog { case: 3 },
    MlpCase::Analog { case: 4 },
];

pub const LSTM_CASES: [LstmCase; 7] = [
    LstmCase::Digital { cores: 1 },
    LstmCase::Digital { cores: 2 },
    LstmCase::Digital { cores: 5 },
    LstmCase::Analog { case: 1 },
    LstmCase::Analog { case: 2 },
    LstmCase::Analog { case: 3 },
    LstmCase::Analog { case: 4 },
];

pub const LSTM_SIZES: [u64; 3] = [256, 512, 750];

/// Fig. 7: all MLP cases on both systems.
pub fn fig7_mlp(n_inf: u32) -> Vec<CaseResult> {
    let mut out = Vec::new();
    for kind in SystemKind::ALL {
        let cfg = SystemConfig::for_kind(kind);
        for case in MLP_CASES {
            out.push(run_workload(kind, mlp::generate(case, &cfg, n_inf)));
        }
    }
    out
}

/// Fig. 8: sub-ROI breakdown for the MLP reference + analog cases 1/3/4
/// (case 2's distribution matches case 1, as the paper notes).
pub fn fig8_mlp_breakdown(n_inf: u32) -> Vec<CaseResult> {
    let mut out = Vec::new();
    for kind in SystemKind::ALL {
        let cfg = SystemConfig::for_kind(kind);
        for case in [
            MlpCase::Digital { cores: 1 },
            MlpCase::Analog { case: 1 },
            MlpCase::Analog { case: 3 },
            MlpCase::Analog { case: 4 },
        ] {
            out.push(run_workload(kind, mlp::generate(case, &cfg, n_inf)));
        }
    }
    out
}

/// §VII.B: loosely-coupled vs tightly-coupled vs digital single-core.
pub fn loose_vs_tight(n_inf: u32) -> Vec<CaseResult> {
    let mut out = Vec::new();
    for kind in SystemKind::ALL {
        let cfg = SystemConfig::for_kind(kind);
        for case in [
            MlpCase::Digital { cores: 1 },
            MlpCase::Analog { case: 1 },
            MlpCase::AnalogLoose,
        ] {
            out.push(run_workload(kind, mlp::generate(case, &cfg, n_inf)));
        }
    }
    out
}

/// Fig. 10: all LSTM cases x sizes x systems.
pub fn fig10_lstm(n_inf: u32) -> Vec<CaseResult> {
    let mut out = Vec::new();
    for kind in SystemKind::ALL {
        let cfg = SystemConfig::for_kind(kind);
        for n_h in LSTM_SIZES {
            for case in LSTM_CASES {
                out.push(run_workload(kind, lstm::generate(case, n_h, &cfg, n_inf)));
            }
        }
    }
    out
}

/// Fig. 11: LSTM analog sub-ROI breakdown (high-power, all sizes).
pub fn fig11_lstm_breakdown(n_inf: u32) -> Vec<CaseResult> {
    let cfg = SystemConfig::high_power();
    let mut out = Vec::new();
    for n_h in LSTM_SIZES {
        for case in [
            LstmCase::Analog { case: 1 },
            LstmCase::Analog { case: 2 },
            LstmCase::Analog { case: 3 },
            LstmCase::Analog { case: 4 },
        ] {
            out.push(run_workload(
                SystemKind::HighPower,
                lstm::generate(case, n_h, &cfg, n_inf),
            ));
        }
    }
    out
}

/// Fig. 13: CNN F/M/S, digital vs analog, both systems.
pub fn fig13_cnn(n_inf: u32) -> Vec<CaseResult> {
    let mut out = Vec::new();
    for kind in SystemKind::ALL {
        let cfg = SystemConfig::for_kind(kind);
        for variant in CnnVariant::ALL {
            for case in [CnnCase::Digital, CnnCase::Analog] {
                out.push(run_workload(kind, cnn::generate(case, variant, &cfg, n_inf)));
            }
        }
    }
    out
}

/// Fig. 14: CNN-S per-core utilization on the high-power system.
pub fn fig14_cnn_utilization(n_inf: u32) -> Vec<CaseResult> {
    let cfg = SystemConfig::high_power();
    vec![
        run_workload(
            SystemKind::HighPower,
            cnn::generate(CnnCase::Digital, CnnVariant::Slow, &cfg, n_inf),
        ),
        run_workload(
            SystemKind::HighPower,
            cnn::generate(CnnCase::Analog, CnnVariant::Slow, &cfg, n_inf),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_row_count() {
        let rows = fig7_mlp(1);
        assert_eq!(rows.len(), 2 * 7);
    }

    #[test]
    fn loose_tight_ordering_holds() {
        // §VII.B: tight > loose > digital.
        let rows = loose_vs_tight(2);
        let hp: Vec<&CaseResult> = rows
            .iter()
            .filter(|r| r.system == SystemKind::HighPower)
            .collect();
        let dig = hp.iter().find(|r| r.label.contains("DIG")).unwrap();
        let tight = hp.iter().find(|r| r.label.contains("case1")).unwrap();
        let loose = hp.iter().find(|r| r.label.contains("loose")).unwrap();
        assert!(tight.time_s < loose.time_s, "tight faster than loose");
        assert!(loose.time_s < dig.time_s, "loose faster than digital");
    }
}
