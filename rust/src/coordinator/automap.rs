//! Automap validation: simulate the search's surviving candidates on
//! the target system — fanned out across the same worker pool as the
//! figure sweeps — and compute the Pareto front on *simulated*
//! (cycles, energy). The all-digital single-core baseline always rides
//! along, so every report answers "how much better than naive?".
//!
//! Determinism: the candidate list is produced serially by
//! `workload::automap::search`, `parallel_map` preserves input order,
//! every simulation is self-contained, and Pareto/best selection break
//! ties on the candidate descriptor — so rows are bit-identical at any
//! `--jobs N` (enforced by `tests/automap.rs`).

use crate::config::{SystemConfig, SystemKind};
use crate::nn::LayerGraph;
use crate::util::parallel;
use crate::workload::automap::{self, Candidate, CostModel, SearchOptions, TopologyBudget};
use crate::workload::compile::cache::{CompileCache, CompileCacheStats};
use crate::workload::{compile, WorkloadError};
use std::sync::Mutex;

use super::{run_workload, CaseResult, RunOptions};

#[derive(Clone, Copy, Debug)]
pub struct AutomapOptions {
    /// Candidates validated by simulation (plus energy-ranked extras).
    pub top_k: usize,
    /// Inferences per validation run.
    pub n_inf: u32,
    /// Worker threads for the search fan-out and the validation fan-out.
    pub jobs: usize,
    /// Cost engine used to rank the space (compositional by default;
    /// `Compiled` is the full-trace oracle knob).
    pub model: CostModel,
    /// `Some(n)`: legacy capped-exhaustive enumeration. `None`:
    /// branch-and-bound over the whole space.
    pub cap: Option<usize>,
    /// Deepest pipeline partition searched (1..=8).
    pub depth: usize,
    /// Largest column-replication factor searched (of {1, 2, 4, 8}).
    pub max_replica: usize,
    /// Share lowered step fragments across `Compiled`-oracle scoring and
    /// the top-K validation compiles (bit-identical output either way).
    pub compile_cache: bool,
}

impl Default for AutomapOptions {
    fn default() -> AutomapOptions {
        AutomapOptions {
            top_k: 8,
            n_inf: 5,
            jobs: 1,
            model: CostModel::Compositional,
            cap: None,
            depth: 8,
            max_replica: 8,
            compile_cache: true,
        }
    }
}

/// One validated candidate.
pub struct AutomapRow {
    pub desc: String,
    /// Analytic estimate that ranked this candidate.
    pub est_cycles: f64,
    /// Full simulation outcome.
    pub result: CaseResult,
    /// On the Pareto front of simulated (time, energy).
    pub pareto: bool,
    /// This row is the all-digital single-core baseline.
    pub baseline: bool,
}

pub struct AutomapReport {
    pub enumerated: usize,
    /// Candidates skipped by branch-and-bound lower bounds.
    pub pruned: usize,
    pub feasible: usize,
    pub truncated: bool,
    pub rows: Vec<AutomapRow>,
    /// Index of the fastest simulated row.
    pub best: usize,
    /// Index of the baseline row.
    pub baseline: usize,
    /// Compile-cache counters of the `Compiled`-oracle search, if it ran
    /// with the cache enabled (excluded from row-identity comparisons).
    pub search_cache: Option<CompileCacheStats>,
    /// Compile-cache counters of the top-K validation compiles, if they
    /// ran with the cache enabled.
    pub validate_cache: Option<CompileCacheStats>,
}

impl AutomapReport {
    pub fn best_row(&self) -> &AutomapRow {
        &self.rows[self.best]
    }

    pub fn baseline_row(&self) -> &AutomapRow {
        &self.rows[self.baseline]
    }

    pub fn speedup_vs_baseline(&self) -> f64 {
        self.baseline_row().result.time_s / self.best_row().result.time_s
    }

    pub fn front(&self) -> impl Iterator<Item = &AutomapRow> {
        self.rows.iter().filter(|r| r.pareto)
    }
}

/// Search the mapping space and validate the survivors on `kind`.
pub fn run_search(
    graph: &LayerGraph,
    budget: &TopologyBudget,
    kind: SystemKind,
    opts: AutomapOptions,
) -> Result<AutomapReport, WorkloadError> {
    let cfg = SystemConfig::for_kind(kind);
    if budget.cores > cfg.num_cores {
        return Err(WorkloadError::InvalidMapping(format!(
            "budget of {} cores exceeds the {} system's {} cores",
            budget.cores,
            kind.name(),
            cfg.num_cores
        )));
    }
    let outcome = automap::search_opts(
        graph,
        budget,
        &cfg,
        &SearchOptions {
            top_k: opts.top_k,
            model: opts.model,
            cap: opts.cap,
            max_depth: opts.depth,
            max_replica: opts.max_replica,
            jobs: opts.jobs,
            compile_cache: opts.compile_cache,
        },
    )?;
    let (base_mapping, base_desc) = automap::digital_baseline(graph)?;

    let mut cands = outcome.ranked;
    let baseline_idx = match cands.iter().position(|c| c.desc == base_desc) {
        Some(i) => i,
        None => {
            let est = automap::estimate(graph, &base_mapping, &cfg)?;
            cands.push(Candidate { mapping: base_mapping, desc: base_desc, est });
            cands.len() - 1
        }
    };

    // The top-K compiles share one materialize-mode fragment cache:
    // step lowerings repeat across inferences (emission is i-invariant)
    // and across candidates that place the same anchors, so the winners'
    // full traces splice mostly-cached fragments. Output is
    // bit-identical to plain `compile` (debug builds assert per hit).
    let vcache = opts.compile_cache.then(|| Mutex::new(CompileCache::new(true)));
    let workloads = cands
        .iter()
        .map(|c| match &vcache {
            Some(vc) => {
                let mut ctx = compile::CacheCtx::materialize(vc);
                compile::compile_with(graph, &c.mapping, opts.n_inf, Some(&mut ctx))
            }
            None => compile::compile(graph, &c.mapping, opts.n_inf),
        })
        .collect::<Result<Vec<_>, _>>()?;
    // `parallel_map` preserves input order, so the first failing
    // candidate (in rank order, not worker order) aborts the validation.
    let ro = RunOptions { jobs: Some(opts.jobs), ..RunOptions::default() };
    let results =
        parallel::parallel_map(workloads, ro.jobs.unwrap_or(1), |w| run_workload(kind, w, &ro))
            .into_iter()
            .collect::<Result<Vec<_>, _>>()?;

    let mut rows: Vec<AutomapRow> = cands
        .into_iter()
        .zip(results)
        .enumerate()
        .map(|(i, (c, result))| AutomapRow {
            desc: c.desc,
            est_cycles: c.est.cycles_per_inf,
            result,
            pareto: false,
            baseline: i == baseline_idx,
        })
        .collect();

    let metrics: Vec<(f64, f64)> =
        rows.iter().map(|r| (r.result.time_s, r.result.energy.total_j())).collect();
    for (i, row) in rows.iter_mut().enumerate() {
        let (ti, ei) = metrics[i];
        row.pareto = !metrics
            .iter()
            .enumerate()
            .any(|(j, &(tj, ej))| j != i && tj <= ti && ej <= ei && (tj < ti || ej < ei));
    }
    let best = (0..rows.len())
        .min_by(|&a, &b| {
            rows[a]
                .result
                .time_s
                .total_cmp(&rows[b].result.time_s)
                .then_with(|| rows[a].desc.cmp(&rows[b].desc))
        })
        .expect("at least the baseline row exists");

    Ok(AutomapReport {
        enumerated: outcome.enumerated,
        pruned: outcome.pruned,
        feasible: outcome.feasible,
        truncated: outcome.truncated,
        rows,
        best,
        baseline: baseline_idx,
        search_cache: outcome.cache,
        validate_cache: vcache
            .map(|c| c.into_inner().expect("compile cache poisoned").stats()),
    })
}
