//! System configurations — the paper's Table I, verbatim.
//!
//! Two target systems (§VI.A): a *low-power* edge configuration
//! (0.8 GHz, 32 kB L1, 512 kB LLC) and a *high-power* configuration
//! (2.3 GHz, 64 kB L1, 1 MB LLC). Both are 8-core in-order (MinorCPU)
//! ARMv8 systems with DDR4-2400 memory.

pub(crate) mod power;

pub use power::{AimcEnergyModel, PowerModel};

/// Which of the paper's two system configurations (Table I-A columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SystemKind {
    LowPower,
    HighPower,
}

impl SystemKind {
    pub const ALL: [SystemKind; 2] = [SystemKind::LowPower, SystemKind::HighPower];

    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::LowPower => "low-power",
            SystemKind::HighPower => "high-power",
        }
    }

    pub fn parse(s: &str) -> Option<SystemKind> {
        match s {
            "low" | "low-power" | "lp" => Some(SystemKind::LowPower),
            "high" | "high-power" | "hp" => Some(SystemKind::HighPower),
            _ => None,
        }
    }
}

/// Cache geometry.
#[derive(Clone, Copy, Debug)]
pub struct CacheGeometry {
    pub size_bytes: u64,
    pub assoc: u32,
    pub line_bytes: u64,
    /// Hit latency in core cycles.
    pub hit_latency_cycles: u64,
}

impl CacheGeometry {
    pub fn sets(&self) -> u64 {
        self.size_bytes / (self.line_bytes * self.assoc as u64)
    }
}

/// Full-system configuration (Table I-A).
#[derive(Clone, Debug)]
pub struct SystemConfig {
    pub kind: SystemKind,
    pub num_cores: usize,
    /// Core clock in Hz.
    pub freq_hz: f64,
    pub vdd: f64,
    pub l1d: CacheGeometry,
    pub l1i: CacheGeometry,
    pub llc: CacheGeometry,
    /// DDR4 interface: peak bytes per second.
    pub dram_peak_bps: f64,
    /// Average DRAM access latency (controller + device), seconds.
    pub dram_latency_s: f64,
    /// Memory bus width in bytes per bus cycle (Table I-A: 16b).
    pub membus_width_bytes: u64,
    /// Memory bus frontend latency, bus cycles (Table I-A: 3).
    pub membus_frontend_cycles: u64,
    /// Forward / response / snoop latencies, bus cycles (Table I-A: 4).
    pub membus_fwd_cycles: u64,
    pub power: PowerModel,
    pub aimc: AimcConfig,
}

/// AIMC tile parameters (Table I-C).
#[derive(Clone, Copy, Debug)]
pub struct AimcConfig {
    /// Fixed MVM latency of one crossbar process, seconds (100 ns).
    pub process_latency_s: f64,
    /// Input/output data throughput between CPU and tile (4 GB/s).
    pub io_throughput_bps: f64,
    /// MVM energy efficiency of a 256x256 tile, ops per joule
    /// (12.8 TOp/s/W == 12.8e12 ops/J), *before* node upscaling.
    pub tops_per_watt_256: f64,
    /// Technology-node power upscaling factor (alpha*beta^2, §VI.B):
    /// 5.3x for the high-power system, 2x for the low-power system.
    pub node_power_scale: f64,
    /// Physical crossbar dimensions of one tile used by default mappings.
    pub tile_rows: u32,
    pub tile_cols: u32,
    /// Extra per-transaction latency when the tile hangs off the I/O bus
    /// (loose coupling, §IV.A / §VII.B), seconds per transaction.
    pub pio_transaction_s: f64,
    /// Loose-coupling effective throughput over the peripheral bus.
    pub pio_throughput_bps: f64,
}

impl SystemConfig {
    /// Table I-A, low-power column.
    pub fn low_power() -> SystemConfig {
        SystemConfig {
            kind: SystemKind::LowPower,
            num_cores: 8,
            freq_hz: 0.8e9,
            vdd: 0.75,
            l1d: CacheGeometry {
                size_bytes: 32 * 1024,
                assoc: 4,
                line_bytes: 64,
                hit_latency_cycles: 2,
            },
            l1i: CacheGeometry {
                size_bytes: 32 * 1024,
                assoc: 4,
                line_bytes: 64,
                hit_latency_cycles: 1,
            },
            llc: CacheGeometry {
                size_bytes: 512 * 1024,
                assoc: 16,
                line_bytes: 64,
                hit_latency_cycles: 14,
            },
            dram_peak_bps: 19.2e9, // DDR4-2400 x64
            dram_latency_s: 60e-9,
            membus_width_bytes: 16,
            membus_frontend_cycles: 3,
            membus_fwd_cycles: 4,
            power: PowerModel::low_power(),
            aimc: AimcConfig::for_kind(SystemKind::LowPower),
        }
    }

    /// Table I-A, high-power column.
    pub fn high_power() -> SystemConfig {
        SystemConfig {
            kind: SystemKind::HighPower,
            num_cores: 8,
            freq_hz: 2.3e9,
            vdd: 1.3,
            l1d: CacheGeometry {
                size_bytes: 64 * 1024,
                assoc: 4,
                line_bytes: 64,
                hit_latency_cycles: 2,
            },
            l1i: CacheGeometry {
                size_bytes: 64 * 1024,
                assoc: 4,
                line_bytes: 64,
                hit_latency_cycles: 1,
            },
            llc: CacheGeometry {
                size_bytes: 1024 * 1024,
                assoc: 16,
                line_bytes: 64,
                hit_latency_cycles: 18,
            },
            dram_peak_bps: 19.2e9,
            dram_latency_s: 55e-9,
            membus_width_bytes: 16,
            membus_frontend_cycles: 3,
            membus_fwd_cycles: 4,
            power: PowerModel::high_power(),
            aimc: AimcConfig::for_kind(SystemKind::HighPower),
        }
    }

    pub fn for_kind(kind: SystemKind) -> SystemConfig {
        match kind {
            SystemKind::LowPower => SystemConfig::low_power(),
            SystemKind::HighPower => SystemConfig::high_power(),
        }
    }

    /// Core clock period in picoseconds (integer; simulation time unit).
    pub fn cycle_ps(&self) -> u64 {
        (1e12 / self.freq_hz).round() as u64
    }

    /// Convert core cycles to picoseconds.
    pub fn cycles_to_ps(&self, cycles: u64) -> u64 {
        cycles * self.cycle_ps()
    }

    /// Convert seconds to picoseconds.
    pub fn s_to_ps(s: f64) -> u64 {
        (s * 1e12).round() as u64
    }
}

impl AimcConfig {
    pub fn for_kind(kind: SystemKind) -> AimcConfig {
        AimcConfig {
            process_latency_s: 100e-9,
            io_throughput_bps: 4.0e9,
            tops_per_watt_256: 12.8e12,
            node_power_scale: match kind {
                SystemKind::HighPower => 5.3,
                SystemKind::LowPower => 2.0,
            },
            tile_rows: 256,
            tile_cols: 256,
            // Per-driver-call latency of a batched uncached transfer over
            // the peripheral bus (doorbell + completion round trip), plus
            // a sustained-throughput cap well below the tight port's
            // 4 GB/s. CALIBRATED so the loosely-coupled MLP lands at the
            // paper's ~4.1x-over-digital / ~3.1x-slower-than-tight point
            // (§VII.B).
            pio_transaction_s: 16.0e-6,
            pio_throughput_bps: 0.3e9,
        }
    }

    /// Energy of one MVM process on an (rows x cols) tile, joules.
    ///
    /// Table I-C gives 12.8 TOp/s/W for a 256x256 tile; one MVM is
    /// 2*rows*cols ops. The paper re-calculates energy for other tile
    /// sizes "considering the crossbar array size as well as data
    /// converters": the crossbar term scales with rows*cols, the
    /// converter term with (rows DACs + cols ADCs). We apportion the
    /// 256x256 reference energy ~40% crossbar / ~60% converters (HERMES
    /// [13]: ADCs dominate the tile energy), then apply the
    /// technology-node power upscaling (§VI.B).
    pub fn mvm_energy_j(&self, rows: u32, cols: u32) -> f64 {
        let ref_ops = 2.0 * 256.0 * 256.0;
        let ref_energy = ref_ops / self.tops_per_watt_256; // J per 256x256 MVM
        let xbar_ref = 0.4 * ref_energy;
        let conv_ref = 0.6 * ref_energy;
        let xbar = xbar_ref * (rows as f64 * cols as f64) / (256.0 * 256.0);
        let conv = conv_ref * (rows as f64 + cols as f64) / (256.0 + 256.0);
        (xbar + conv) * self.node_power_scale
    }

    /// Energy to move one byte over the tile queue/dequeue path, joules.
    /// SRAM access + link: ~1 pJ/B at 14 nm, node-upscaled.
    pub fn io_energy_j_per_byte(&self) -> f64 {
        1.0e-12 * self.node_power_scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1a_values() {
        let lp = SystemConfig::low_power();
        let hp = SystemConfig::high_power();
        assert_eq!(lp.num_cores, 8);
        assert_eq!(hp.num_cores, 8);
        assert_eq!(lp.freq_hz, 0.8e9);
        assert_eq!(hp.freq_hz, 2.3e9);
        assert_eq!(lp.l1d.size_bytes, 32 * 1024);
        assert_eq!(hp.l1d.size_bytes, 64 * 1024);
        assert_eq!(lp.llc.size_bytes, 512 * 1024);
        assert_eq!(hp.llc.size_bytes, 1024 * 1024);
        assert_eq!(lp.membus_width_bytes, 16);
        assert_eq!(lp.membus_frontend_cycles, 3);
        assert_eq!(lp.membus_fwd_cycles, 4);
        assert_eq!(lp.vdd, 0.75);
        assert_eq!(hp.vdd, 1.3);
    }

    #[test]
    fn cycle_periods() {
        assert_eq!(SystemConfig::low_power().cycle_ps(), 1250);
        assert_eq!(SystemConfig::high_power().cycle_ps(), 435);
    }

    #[test]
    fn cache_geometry_sets() {
        let lp = SystemConfig::low_power();
        assert_eq!(lp.l1d.sets(), 32 * 1024 / (64 * 4));
        assert_eq!(lp.llc.sets(), 512 * 1024 / (64 * 16));
    }

    #[test]
    fn table1c_values() {
        let a = AimcConfig::for_kind(SystemKind::HighPower);
        assert_eq!(a.process_latency_s, 100e-9);
        assert_eq!(a.io_throughput_bps, 4.0e9);
        assert_eq!(a.tops_per_watt_256, 12.8e12);
        assert_eq!(a.node_power_scale, 5.3);
        assert_eq!(AimcConfig::for_kind(SystemKind::LowPower).node_power_scale, 2.0);
    }

    #[test]
    fn mvm_energy_reference_point() {
        // Before node scaling, a 256x256 MVM must cost exactly
        // 2*256*256 / 12.8e12 J; check by dividing the scale back out.
        let a = AimcConfig::for_kind(SystemKind::HighPower);
        let e = a.mvm_energy_j(256, 256) / a.node_power_scale;
        let expect = 2.0 * 256.0 * 256.0 / 12.8e12;
        assert!((e - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn mvm_energy_scales_down_with_tile() {
        let a = AimcConfig::for_kind(SystemKind::LowPower);
        assert!(a.mvm_energy_j(128, 128) < a.mvm_energy_j(256, 256));
        // Converter term keeps small tiles from scaling quadratically.
        let ratio = a.mvm_energy_j(256, 256) / a.mvm_energy_j(128, 128);
        assert!(ratio < 4.0 && ratio > 2.0, "{ratio}");
    }

    #[test]
    fn kind_parsing() {
        assert_eq!(SystemKind::parse("hp"), Some(SystemKind::HighPower));
        assert_eq!(SystemKind::parse("low-power"), Some(SystemKind::LowPower));
        assert_eq!(SystemKind::parse("x"), None);
    }
}
