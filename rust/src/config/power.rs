//! System energy and power figures — the paper's Table I-B, verbatim.
//!
//! The core/cache model is a 28 nm bulk ARM Cortex-A53 system (gem5-X
//! calibration [15]); DRAM energy follows [36]. Full-system energy is the
//! sum of core, cache, and DRAM components computed from simulation
//! statistics (§VI.A).

use super::SystemKind;

/// Table I-B: per-system energy/power figures.
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    /// Idle core energy per cycle, joules (Table I-B pJ/cycle).
    pub idle_core_j_per_cycle: f64,
    /// WFM (wait-for-memory) core energy per cycle, joules.
    pub wfm_core_j_per_cycle: f64,
    /// Active core energy per cycle, joules.
    pub active_core_j_per_cycle: f64,
    /// Memory controller + IO static power, watts.
    pub mem_ctrl_io_w: f64,
    /// LLC leakage per 256 kB, watts.
    pub llc_leak_w_per_256k: f64,
    /// LLC read energy per byte, joules.
    pub llc_read_j_per_byte: f64,
    /// LLC write energy per byte, joules.
    pub llc_write_j_per_byte: f64,
    /// DRAM energy per access, joules (per 64-byte access, [36]).
    pub dram_j_per_access: f64,
}

impl PowerModel {
    pub fn low_power() -> PowerModel {
        PowerModel {
            idle_core_j_per_cycle: 10.72e-12,
            wfm_core_j_per_cycle: 46.04e-12,
            active_core_j_per_cycle: 60.92e-12,
            mem_ctrl_io_w: 3.03,
            llc_leak_w_per_256k: 271.62e-3,
            llc_read_j_per_byte: 1.81e-12,
            llc_write_j_per_byte: 1.63e-12,
            dram_j_per_access: 120.0e-12,
        }
    }

    pub fn high_power() -> PowerModel {
        PowerModel {
            idle_core_j_per_cycle: 126.03e-12,
            wfm_core_j_per_cycle: 638.99e-12,
            active_core_j_per_cycle: 845.39e-12,
            mem_ctrl_io_w: 5.82,
            llc_leak_w_per_256k: 874.08e-3,
            llc_read_j_per_byte: 5.60e-12,
            llc_write_j_per_byte: 5.02e-12,
            dram_j_per_access: 120.0e-12,
        }
    }

    pub fn for_kind(kind: SystemKind) -> PowerModel {
        match kind {
            SystemKind::LowPower => PowerModel::low_power(),
            SystemKind::HighPower => PowerModel::high_power(),
        }
    }

    /// LLC leakage power for a given capacity, watts.
    pub fn llc_leakage_w(&self, llc_bytes: u64) -> f64 {
        self.llc_leak_w_per_256k * (llc_bytes as f64 / (256.0 * 1024.0))
    }
}

/// Marker trait alias re-exported for AIMC energy (lives in AimcConfig).
pub type AimcEnergyModel = super::AimcConfig;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1b_values_low_power() {
        let p = PowerModel::low_power();
        assert_eq!(p.idle_core_j_per_cycle, 10.72e-12);
        assert_eq!(p.wfm_core_j_per_cycle, 46.04e-12);
        assert_eq!(p.active_core_j_per_cycle, 60.92e-12);
        assert_eq!(p.mem_ctrl_io_w, 3.03);
        assert_eq!(p.dram_j_per_access, 120.0e-12);
    }

    #[test]
    fn table1b_values_high_power() {
        let p = PowerModel::high_power();
        assert_eq!(p.active_core_j_per_cycle, 845.39e-12);
        assert_eq!(p.llc_read_j_per_byte, 5.60e-12);
        assert_eq!(p.llc_write_j_per_byte, 5.02e-12);
        assert_eq!(p.llc_leak_w_per_256k, 874.08e-3);
    }

    #[test]
    fn state_energy_ordering() {
        for p in [PowerModel::low_power(), PowerModel::high_power()] {
            assert!(p.idle_core_j_per_cycle < p.wfm_core_j_per_cycle);
            assert!(p.wfm_core_j_per_cycle < p.active_core_j_per_cycle);
        }
    }

    #[test]
    fn llc_leakage_scales_with_capacity() {
        let p = PowerModel::high_power();
        let one_mb = p.llc_leakage_w(1024 * 1024);
        let half_mb = p.llc_leakage_w(512 * 1024);
        assert!((one_mb - 2.0 * half_mb).abs() < 1e-12);
        assert!((one_mb - 4.0 * 874.08e-3).abs() < 1e-9);
    }
}
