//! Artifact manifest parsing.
//!
//! `aot.py` writes one line-based manifest per model bundle:
//!
//! ```text
//! model mlp_analog_b1
//! hlo mlp_analog_b1.hlo.txt
//! input x f32 1,1024 mlp_analog_b1.x.bin
//! param w1_prog f32 1024,1024 mlp.w1_prog.bin
//! probe_out mlp_analog_b1.probe_out.bin
//! ```

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub file: PathBuf,
}

impl TensorMeta {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub hlo: PathBuf,
    pub inputs: Vec<TensorMeta>,
    pub params: Vec<TensorMeta>,
    pub probe_out: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path, name: &str) -> Result<Manifest> {
        let path = dir.join(format!("{name}.manifest"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let mut model = None;
        let mut hlo = None;
        let mut probe_out = None;
        let mut inputs = Vec::new();
        let mut params = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let parts: Vec<&str> = line.split_whitespace().collect();
            match parts.as_slice() {
                [] => {}
                ["model", m] => model = Some(m.to_string()),
                ["hlo", f] => hlo = Some(dir.join(f)),
                ["probe_out", f] => probe_out = Some(dir.join(f)),
                [kind @ ("input" | "param"), name, "f32", shape, file] => {
                    let shape: Vec<usize> = shape
                        .split(',')
                        .map(|d| d.parse().context("bad shape"))
                        .collect::<Result<_>>()?;
                    let t = TensorMeta {
                        name: name.to_string(),
                        shape,
                        file: dir.join(file),
                    };
                    if *kind == "input" {
                        inputs.push(t);
                    } else {
                        params.push(t);
                    }
                }
                _ => bail!("manifest line {} unparseable: {line:?}", ln + 1),
            }
        }
        Ok(Manifest {
            model: model.context("missing model line")?,
            hlo: hlo.context("missing hlo line")?,
            inputs,
            params,
            probe_out: probe_out.context("missing probe_out line")?,
        })
    }

    /// All runtime arguments in HLO parameter order: inputs then params.
    pub fn arg_order(&self) -> impl Iterator<Item = &TensorMeta> {
        self.inputs.iter().chain(self.params.iter())
    }
}

/// Read a little-endian f32 binary tensor file.
pub fn read_f32_bin(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading tensor {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "model demo\nhlo demo.hlo.txt\ninput x f32 1,8 demo.x.bin\nparam w f32 8,4 demo.w.bin\nprobe_out demo.probe.bin\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.model, "demo");
        assert_eq!(m.inputs.len(), 1);
        assert_eq!(m.params.len(), 1);
        assert_eq!(m.inputs[0].shape, vec![1, 8]);
        assert_eq!(m.params[0].elements(), 32);
        assert!(m.hlo.ends_with("demo.hlo.txt"));
        let order: Vec<&str> = m.arg_order().map(|t| t.name.as_str()).collect();
        assert_eq!(order, vec!["x", "w"]);
    }

    #[test]
    fn rejects_garbage_lines() {
        assert!(Manifest::parse(Path::new("."), "nonsense line here\n").is_err());
    }

    #[test]
    fn requires_model_and_hlo() {
        assert!(Manifest::parse(Path::new("."), "model a\nprobe_out p\n").is_err());
    }

    #[test]
    fn read_f32_roundtrip() {
        let dir = std::env::temp_dir().join("alpine_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        assert_eq!(read_f32_bin(&p).unwrap(), vals);
    }
}
