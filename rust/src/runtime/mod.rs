//! The PJRT runtime: loads the AOT-compiled Layer-2 artifacts (HLO text
//! emitted by `python/compile/aot.py`) and executes them on the XLA CPU
//! client. This is the *functional* inference path used by the e2e
//! examples and the cross-layer validation tests; Python is never on it.
//!
//! Interchange is HLO text — the image's xla_extension 0.5.1 rejects
//! jax>=0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md).

pub mod manifest;

pub use manifest::{read_f32_bin, Manifest, TensorMeta};

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// A compiled model with its weights resident as PJRT-ready literals.
pub struct LoadedModel {
    pub manifest: Manifest,
    exe: xla::PjRtLoadedExecutable,
    /// Weight literals in parameter order (after the inputs).
    param_literals: Vec<xla::Literal>,
}

/// The runtime: one PJRT CPU client + the artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
}

impl Runtime {
    /// Create against an artifacts directory (default: ./artifacts).
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, dir: artifacts_dir.to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Model names listed in the artifacts INDEX.
    pub fn available_models(&self) -> Result<Vec<String>> {
        let text = std::fs::read_to_string(self.dir.join("INDEX"))
            .context("reading artifacts INDEX (run `make artifacts`)")?;
        Ok(text.split_whitespace().map(|s| s.to_string()).collect())
    }

    /// Load + compile one model bundle and pre-stage its weights.
    pub fn load(&self, name: &str) -> Result<LoadedModel> {
        let manifest = Manifest::load(&self.dir, name)?;
        let proto = xla::HloModuleProto::from_text_file(
            manifest.hlo.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", manifest.hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name} on PJRT"))?;

        let mut param_literals = Vec::new();
        for p in &manifest.params {
            param_literals.push(load_literal(p)?);
        }
        Ok(LoadedModel { manifest, exe, param_literals })
    }
}

/// Read a tensor file into a shaped f32 literal.
pub fn load_literal(meta: &TensorMeta) -> Result<xla::Literal> {
    let data = read_f32_bin(&meta.file)?;
    anyhow::ensure!(
        data.len() == meta.elements(),
        "{}: file has {} elements, manifest says {}",
        meta.name,
        data.len(),
        meta.elements()
    );
    literal_from_vec(&data, &meta.shape)
}

/// Build a shaped f32 literal from a flat row-major slice.
pub fn literal_from_vec(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Re-materialize a literal (the xla crate's Literal is not Clone).
fn clone_literal(l: &xla::Literal) -> Result<xla::Literal> {
    let dims = l.array_shape()?.dims().to_vec();
    let data = l.to_vec::<f32>()?;
    Ok(xla::Literal::vec1(&data).reshape(&dims)?)
}

impl LoadedModel {
    pub fn name(&self) -> &str {
        &self.manifest.model
    }

    /// Execute with caller-supplied inputs (shapes per the manifest).
    /// Returns every output of the (tupled) computation as flat f32.
    pub fn run(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            inputs.len() == self.manifest.inputs.len(),
            "{} expects {} inputs, got {}",
            self.manifest.model,
            self.manifest.inputs.len(),
            inputs.len()
        );
        let mut args: Vec<xla::Literal> = Vec::new();
        for (meta, data) in self.manifest.inputs.iter().zip(inputs) {
            anyhow::ensure!(
                data.len() == meta.elements(),
                "input {}: got {} elements, want {}",
                meta.name,
                data.len(),
                meta.elements()
            );
            args.push(literal_from_vec(data, &meta.shape)?);
        }
        for p in &self.param_literals {
            args.push(clone_literal(p)?);
        }
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts.into_iter().map(|l| Ok(l.to_vec::<f32>()?)).collect()
    }

    /// Run the baked probe inputs and compare against the expected output
    /// recorded at AOT time. Returns (max_abs_err, rel_l2_err).
    pub fn probe_check(&self) -> Result<(f64, f64)> {
        let inputs: Vec<Vec<f32>> = self
            .manifest
            .inputs
            .iter()
            .map(|m| read_f32_bin(&m.file))
            .collect::<Result<_>>()?;
        let got = self.run(&inputs)?;
        let expect = read_f32_bin(&self.manifest.probe_out)?;
        let first = &got[0];
        anyhow::ensure!(
            first.len() == expect.len(),
            "probe length mismatch: {} vs {}",
            first.len(),
            expect.len()
        );
        let mut max_abs = 0.0f64;
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in first.iter().zip(expect.iter()) {
            max_abs = max_abs.max((a - b).abs() as f64);
            num += ((a - b) * (a - b)) as f64;
            den += (b * b) as f64;
        }
        Ok((max_abs, (num / den.max(1e-30)).sqrt()))
    }
}

/// Default artifacts directory: $ALPINE_ARTIFACTS or ./artifacts.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("ALPINE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_from_vec_roundtrip() {
        let l = literal_from_vec(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 2]);
    }

    #[test]
    fn clone_literal_preserves_contents() {
        let l = literal_from_vec(&[5.0, 6.0], &[2]).unwrap();
        let c = clone_literal(&l).unwrap();
        assert_eq!(c.to_vec::<f32>().unwrap(), vec![5.0, 6.0]);
    }

    #[test]
    fn default_dir_nonempty() {
        assert!(!default_artifacts_dir().as_os_str().is_empty());
    }
}
