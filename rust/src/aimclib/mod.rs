//! AIMClib — the paper's software library (§IV.C), in Rust.
//!
//! Mirrors the C API of Fig. 4: `map_matrix` places (and programs) a
//! weight matrix at an x/y offset of a crossbar, `queue_vector` packs and
//! queues inputs into the tile input memory, `aimc_process` fires the
//! MVM, `dequeue_vector` retrieves outputs. Type-casting between f32 and
//! int8 and the activation functions are provided as in the C library.
//!
//! This is the *functional* device (the paper's host-side checker
//! semantics); the *timing* of the same operations is modeled by
//! `sim::aimc` + the trace machine. The e2e examples use both: this for
//! numbers, the simulator for time/energy.

pub mod activation;
pub mod checker;
pub mod faults;

use checker::{AimcSpec, Matrix};

#[derive(Debug)]
pub enum AimclibError {
    DoesNotFit { x: usize, y: usize, rows: usize, cols: usize, xb_rows: usize, xb_cols: usize },
    QueueOverflow(usize, usize),
    DequeueOverflow(usize, usize),
}

// Manual Display/Error impls: thiserror is not in the offline vendor set.
impl std::fmt::Display for AimclibError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AimclibError::DoesNotFit { x, y, rows, cols, xb_rows, xb_cols } => write!(
                f,
                "matrix ({rows}x{cols}) at ({x},{y}) exceeds crossbar ({xb_rows}x{xb_cols})"
            ),
            AimclibError::QueueOverflow(len, cap) => {
                write!(f, "queue length {len} exceeds input memory {cap}")
            }
            AimclibError::DequeueOverflow(len, cap) => {
                write!(f, "dequeue length {len} exceeds output memory {cap}")
            }
        }
    }
}

impl std::error::Error for AimclibError {}

/// A functional AIMC device: crossbar conductances + I/O memories.
pub struct AimcDevice {
    rows: usize,
    cols: usize,
    /// Programmed conductance codes (continuous, row-major).
    xbar: Matrix,
    /// Input memory: one int8 per word line (stored as f32 DAC codes).
    input_mem: Vec<f32>,
    /// Output memory: one int8 per bit line (ADC codes).
    output_mem: Vec<f32>,
    spec: AimcSpec,
    processes: u64,
}

impl AimcDevice {
    pub fn new(rows: usize, cols: usize, spec: AimcSpec) -> AimcDevice {
        AimcDevice {
            rows,
            cols,
            xbar: Matrix::zeros(rows, cols),
            input_mem: vec![0.0; rows],
            output_mem: vec![0.0; cols],
            spec,
            processes: 0,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn processes(&self) -> u64 {
        self.processes
    }

    /// Fig. 4 `mapMatrix`: program `w_prog` (pre-noised conductance codes)
    /// at crossbar offset (x, y). Multiple matrices of varying sizes can
    /// be tiled next to each other (§IV.C).
    pub fn map_matrix(
        &mut self,
        x: usize,
        y: usize,
        w_prog: &Matrix,
    ) -> Result<(), AimclibError> {
        if x + w_prog.rows > self.rows || y + w_prog.cols > self.cols {
            return Err(AimclibError::DoesNotFit {
                x,
                y,
                rows: w_prog.rows,
                cols: w_prog.cols,
                xb_rows: self.rows,
                xb_cols: self.cols,
            });
        }
        for r in 0..w_prog.rows {
            for c in 0..w_prog.cols {
                self.xbar.data[(x + r) * self.cols + (y + c)] = w_prog.at(r, c);
            }
        }
        Ok(())
    }

    /// Fig. 4 `queueVector`: DAC-quantize f32 inputs into the input
    /// memory starting at word line `index`.
    pub fn queue_vector(&mut self, index: usize, data: &[f32]) -> Result<(), AimclibError> {
        if index + data.len() > self.rows {
            return Err(AimclibError::QueueOverflow(index + data.len(), self.rows));
        }
        for (i, v) in data.iter().enumerate() {
            self.input_mem[index + i] = (v / self.spec.in_scale)
                .round()
                .clamp(checker::DAC_MIN, checker::DAC_MAX);
        }
        Ok(())
    }

    /// Queue raw int8 values (already quantized by the caller).
    pub fn queue_vector_i8(&mut self, index: usize, data: &[i8]) -> Result<(), AimclibError> {
        if index + data.len() > self.rows {
            return Err(AimclibError::QueueOverflow(index + data.len(), self.rows));
        }
        for (i, v) in data.iter().enumerate() {
            self.input_mem[index + i] = *v as f32;
        }
        Ok(())
    }

    /// Clear the input memory (word lines with zero input contribute no
    /// current, so unused rows are harmless — but explicit clearing
    /// between layers avoids stale charge in multi-matrix tiles).
    pub fn clear_input(&mut self) {
        self.input_mem.iter_mut().for_each(|v| *v = 0.0);
    }

    /// Fig. 4 `aimcProcess`: the analog MVM over the whole crossbar.
    /// Every bit line integrates the currents of all word lines and is
    /// digitized by its ADC into the output memory.
    pub fn process(&mut self) {
        self.processes += 1;
        for j in 0..self.cols {
            let mut partial = 0.0f64;
            for i in 0..self.rows {
                let xq = self.input_mem[i];
                if xq != 0.0 {
                    partial += (xq as f64) * (self.xbar.at(i, j) as f64);
                }
            }
            self.output_mem[j] = (partial as f32 / self.spec.adc_scale)
                .round()
                .clamp(checker::ADC_MIN, checker::ADC_MAX);
        }
    }

    /// Fig. 4 `dequeueVector`: read `out.len()` ADC codes starting at bit
    /// line `index`, dequantized to f32 real units.
    pub fn dequeue_vector(&self, index: usize, out: &mut [f32]) -> Result<(), AimclibError> {
        if index + out.len() > self.cols {
            return Err(AimclibError::DequeueOverflow(index + out.len(), self.cols));
        }
        let s = self.spec.adc_scale * self.spec.in_scale * self.spec.w_scale;
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.output_mem[index + i] * s;
        }
        Ok(())
    }

    /// Raw ADC codes (for digital accumulation across row-split tiles).
    pub fn dequeue_codes(&self, index: usize, out: &mut [f32]) -> Result<(), AimclibError> {
        if index + out.len() > self.cols {
            return Err(AimclibError::DequeueOverflow(index + out.len(), self.cols));
        }
        out.copy_from_slice(&self.output_mem[index..index + out.len()]);
        Ok(())
    }

    pub fn spec(&self) -> &AimcSpec {
        &self.spec
    }
}

/// int8 <-> f32 casting helpers (AIMClib's type-casting templates).
pub fn cast_f32_to_i8(data: &[f32], scale: f32) -> Vec<i8> {
    data.iter()
        .map(|v| (v / scale).round().clamp(-128.0, 127.0) as i8)
        .collect()
}

pub fn cast_i8_to_f32(data: &[i8], scale: f32) -> Vec<f32> {
    data.iter().map(|v| *v as f32 * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use checker::{calibrate, program_weights, quantize_weights};

    fn setup(m: usize, n: usize) -> (Matrix, Matrix, AimcSpec) {
        let mut rng = Rng::new(11);
        let x = Matrix::new(1, m, (0..m).map(|_| rng.normal_f32(1.0)).collect());
        let w = Matrix::new(m, n, (0..m * n).map(|_| rng.normal_f32(0.1)).collect());
        let spec = calibrate(&x, &w, m, n);
        (x, w, spec)
    }

    #[test]
    fn device_matches_checker_single_tile() {
        let (x, w, spec) = setup(64, 32);
        let (w_q, _) = quantize_weights(&w);
        let mut rng = Rng::new(2);
        let w_prog = program_weights(&w_q, 0.01, &mut rng);

        let expected = checker::aimc_mvm(&x, &w_prog, &spec);

        let mut dev = AimcDevice::new(64, 32, spec);
        dev.map_matrix(0, 0, &w_prog).unwrap();
        dev.queue_vector(0, &x.data).unwrap();
        dev.process();
        let mut out = vec![0.0f32; 32];
        dev.dequeue_vector(0, &mut out).unwrap();

        for j in 0..32 {
            assert!(
                (out[j] - expected.at(0, j)).abs() < 1e-4 * (1.0 + expected.at(0, j).abs()),
                "col {j}: {} vs {}",
                out[j],
                expected.at(0, j)
            );
        }
    }

    #[test]
    fn tiled_matrices_at_offsets_are_independent() {
        // Two matrices side by side in one crossbar (the LSTM case-1
        // layout): inputs on one matrix's rows must not disturb the other
        // if its word lines are zero.
        let (x, w, spec) = setup(32, 16);
        let (w_q, _) = quantize_weights(&w);
        let mut dev = AimcDevice::new(64, 48, spec);
        dev.map_matrix(0, 0, &w_q).unwrap();
        dev.map_matrix(32, 16, &w_q).unwrap();

        dev.clear_input();
        dev.queue_vector(0, &x.data).unwrap();
        dev.process();
        let mut out_a = vec![0.0f32; 16];
        dev.dequeue_vector(0, &mut out_a).unwrap();

        // Same input applied to the second matrix's rows instead.
        dev.clear_input();
        dev.queue_vector(32, &x.data).unwrap();
        dev.process();
        let mut out_b = vec![0.0f32; 16];
        dev.dequeue_vector(16, &mut out_b).unwrap();

        for j in 0..16 {
            assert!((out_a[j] - out_b[j]).abs() < 1e-5, "col {j}");
        }
    }

    #[test]
    fn map_bounds_checked() {
        let (_, w, spec) = setup(32, 16);
        let mut dev = AimcDevice::new(32, 16, spec);
        assert!(dev.map_matrix(1, 0, &w).is_err());
        assert!(dev.map_matrix(0, 1, &w).is_err());
        assert!(dev.map_matrix(0, 0, &w).is_ok());
    }

    #[test]
    fn queue_dequeue_bounds_checked() {
        let (_, _, spec) = setup(8, 8);
        let mut dev = AimcDevice::new(8, 8, spec);
        assert!(dev.queue_vector(4, &[0.0; 5]).is_err());
        let mut out = vec![0.0; 5];
        assert!(dev.dequeue_vector(4, &mut out).is_err());
    }

    #[test]
    fn cast_roundtrip_within_half_lsb() {
        let data = vec![0.5, -0.25, 0.126, -1.0];
        let scale = 1.0 / 127.0;
        let i8s = cast_f32_to_i8(&data, scale);
        let back = cast_i8_to_f32(&i8s, scale);
        for (a, b) in data.iter().zip(back.iter()) {
            assert!((a - b).abs() <= scale / 2.0 + 1e-6);
        }
    }

    #[test]
    fn cast_saturates() {
        let i8s = cast_f32_to_i8(&[100.0, -100.0], 0.1);
        assert_eq!(i8s, vec![127, -128]);
    }
}
