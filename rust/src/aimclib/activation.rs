//! Digital activation functions (always CPU-side in the paper, §VIII).
//! Used by the functional checker and the e2e serving path.

pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

pub fn sigmoid(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = 1.0 / (1.0 + (-*v).exp());
    }
}

pub fn tanh(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.tanh();
    }
}

/// Numerically-stable softmax over the whole slice.
pub fn softmax(x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let max = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in x.iter_mut() {
        *v /= sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_clamps_negatives() {
        let mut x = vec![-1.0, 0.0, 2.5];
        relu(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 2.5]);
    }

    #[test]
    fn sigmoid_range_and_midpoint() {
        let mut x = vec![0.0, 10.0, -10.0];
        sigmoid(&mut x);
        assert!((x[0] - 0.5).abs() < 1e-6);
        assert!(x[1] > 0.999 && x[2] < 0.001);
    }

    #[test]
    fn tanh_odd_function() {
        let mut a = vec![0.7];
        let mut b = vec![-0.7];
        tanh(&mut a);
        tanh(&mut b);
        assert!((a[0] + b[0]).abs() < 1e-6);
    }

    #[test]
    fn softmax_is_distribution() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        softmax(&mut x);
        let sum: f32 = x.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(x.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn softmax_stable_for_large_inputs() {
        let mut x = vec![1000.0, 1001.0];
        softmax(&mut x);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_empty_noop() {
        let mut x: Vec<f32> = vec![];
        softmax(&mut x);
    }
}
