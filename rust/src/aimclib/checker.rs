//! The AIMClib "checker": a host-side functional simulation of the AIMC
//! tile signal chain (paper §IV.C — "a checker program that simulates
//! tightly-coupled AIMC tiles in guest software so that programs ... can
//! be debugged on the host machine before engaging the real or simulated
//! hardware").
//!
//! The math here is the *contract* shared with the Layer-1 Pallas kernel
//! (`python/compile/kernels/aimc_mvm.py`) and its jnp oracle (`ref.py`):
//! DAC int8 quantization → per-row-block analog MVM against programmed
//! conductances → per-tile ADC int8 quantization → digital accumulation →
//! dequantization. Integration tests compare this against the
//! PJRT-executed artifacts.

use crate::util::rng::Rng;

pub const DAC_MIN: f32 = -128.0;
pub const DAC_MAX: f32 = 127.0;
pub const ADC_MIN: f32 = -128.0;
pub const ADC_MAX: f32 = 127.0;
pub const WEIGHT_LEVELS: f32 = 127.0;

/// Static per-matrix scales (mirrors python AimcSpec).
#[derive(Clone, Copy, Debug)]
pub struct AimcSpec {
    pub in_scale: f32,
    pub w_scale: f32,
    pub adc_scale: f32,
    pub tile_rows: usize,
    pub tile_cols: usize,
}

/// Row-major f32 matrix (weights are conductance codes; continuous).
#[derive(Clone, Debug)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }
}

/// Symmetric int8 weight quantization; returns (codes as f32, scale).
pub fn quantize_weights(w: &Matrix) -> (Matrix, f32) {
    let max = w.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let scale = if max == 0.0 { 1.0 } else { max / WEIGHT_LEVELS };
    let data = w
        .data
        .iter()
        .map(|v| (v / scale).round().clamp(-WEIGHT_LEVELS, WEIGHT_LEVELS))
        .collect();
    (Matrix::new(w.rows, w.cols, data), scale)
}

/// Program quantized codes onto PCM with Gaussian conductance noise
/// (sigma relative to full range) — the CM_INITIALIZE-time perturbation.
pub fn program_weights(w_q: &Matrix, sigma: f32, rng: &mut Rng) -> Matrix {
    if sigma <= 0.0 {
        return w_q.clone();
    }
    let data = w_q
        .data
        .iter()
        .map(|v| v + rng.normal_f32(sigma * WEIGHT_LEVELS))
        .collect();
    Matrix::new(w_q.rows, w_q.cols, data)
}

#[inline]
fn dac(x: f32, in_scale: f32) -> f32 {
    (x / in_scale).round().clamp(DAC_MIN, DAC_MAX)
}

#[inline]
fn adc(p: f32, adc_scale: f32) -> f32 {
    (p / adc_scale).round().clamp(ADC_MIN, ADC_MAX)
}

/// The full analog MVM: y[b][n] over a batch of input rows.
/// Accumulation within a tile uses f64 (the analog integral is exact to
/// float precision; f64 keeps the pre-round value stable so results agree
/// with the jnp oracle to within one ADC LSB).
pub fn aimc_mvm(x: &Matrix, w_prog: &Matrix, spec: &AimcSpec) -> Matrix {
    assert_eq!(x.cols, w_prog.rows, "shape mismatch");
    let (batch, m, n) = (x.rows, w_prog.rows, w_prog.cols);
    let tm = spec.tile_rows;
    let blocks = m.div_ceil(tm);
    let mut out = Matrix::zeros(batch, n);

    for b in 0..batch {
        // DAC conversion of the input vector.
        let x_q: Vec<f32> = (0..m).map(|i| dac(x.at(b, i), spec.in_scale)).collect();
        for j in 0..n {
            let mut acc = 0.0f32; // digital accumulator over row-block tiles
            for blk in 0..blocks {
                let lo = blk * tm;
                let hi = ((blk + 1) * tm).min(m);
                let mut partial = 0.0f64; // analog bit-line integral
                for i in lo..hi {
                    partial += (x_q[i] as f64) * (w_prog.at(i, j) as f64);
                }
                acc += adc(partial as f32, spec.adc_scale);
            }
            out.data[b * n + j] = acc * spec.adc_scale * spec.in_scale * spec.w_scale;
        }
    }
    out
}

/// Digital int8 reference MVM with fp32 accumulation (paper baseline).
pub fn digital_mvm(x: &Matrix, w_q: &Matrix, in_scale: f32, w_scale: f32) -> Matrix {
    assert_eq!(x.cols, w_q.rows);
    let (batch, m, n) = (x.rows, w_q.rows, w_q.cols);
    let mut out = Matrix::zeros(batch, n);
    for b in 0..batch {
        let x_q: Vec<f32> = (0..m).map(|i| dac(x.at(b, i), in_scale)).collect();
        for j in 0..n {
            let mut acc = 0.0f64;
            for i in 0..m {
                acc += (x_q[i] as f64) * (w_q.at(i, j) as f64);
            }
            out.data[b * n + j] = acc as f32 * in_scale * w_scale;
        }
    }
    out
}

/// Calibrate scales from probe data (mirrors python `calibrate_spec`).
pub fn calibrate(x_sample: &Matrix, w: &Matrix, tile_rows: usize, tile_cols: usize) -> AimcSpec {
    let xmax = x_sample.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let in_scale = if xmax == 0.0 { 1.0 } else { xmax / DAC_MAX };
    let (w_q, w_scale) = quantize_weights(w);
    let m = w.rows;
    let tm = tile_rows;
    let blocks = m.div_ceil(tm);
    let mut peak = 0.0f64;
    for b in 0..x_sample.rows {
        let x_q: Vec<f32> = (0..m).map(|i| dac(x_sample.at(b, i), in_scale)).collect();
        for j in 0..w.cols {
            for blk in 0..blocks {
                let lo = blk * tm;
                let hi = ((blk + 1) * tm).min(m);
                let mut partial = 0.0f64;
                for i in lo..hi {
                    partial += (x_q[i] as f64) * (w_q.at(i, j) as f64);
                }
                peak = peak.max(partial.abs());
            }
        }
    }
    AimcSpec {
        in_scale,
        w_scale,
        adc_scale: ((peak / ADC_MAX as f64) as f32).max(1.0),
        tile_rows,
        tile_cols,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::miniprop;

    fn rand_matrix(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> Matrix {
        let data = (0..rows * cols).map(|_| rng.normal_f32(scale)).collect();
        Matrix::new(rows, cols, data)
    }

    #[test]
    fn noiseless_tracks_exact_product() {
        let mut rng = Rng::new(1);
        let x = rand_matrix(&mut rng, 2, 96, 1.0);
        let w = rand_matrix(&mut rng, 96, 40, 0.1);
        let (w_q, _) = quantize_weights(&w);
        let spec = calibrate(&x, &w, 48, 40);
        let y = aimc_mvm(&x, &w_q, &spec);
        // exact product
        for b in 0..2 {
            for j in 0..40 {
                let mut exact = 0.0f64;
                for i in 0..96 {
                    exact += x.at(b, i) as f64 * w.at(i, j) as f64;
                }
                let got = y.at(b, j) as f64;
                let tol = (spec.adc_scale * spec.in_scale * spec.w_scale * 3.0) as f64
                    + 0.05 * exact.abs();
                assert!((got - exact).abs() < tol, "b{b} j{j}: {got} vs {exact}");
            }
        }
    }

    #[test]
    fn digital_more_accurate_than_analog_with_noise() {
        let mut rng = Rng::new(3);
        let x = rand_matrix(&mut rng, 4, 128, 1.0);
        let w = rand_matrix(&mut rng, 128, 64, 0.1);
        let (w_q, w_scale) = quantize_weights(&w);
        let w_prog = program_weights(&w_q, 0.03, &mut rng);
        let spec = calibrate(&x, &w, 64, 64);
        let ya = aimc_mvm(&x, &w_prog, &spec);
        let yd = digital_mvm(&x, &w_q, spec.in_scale, w_scale);
        let mut err_a = 0.0;
        let mut err_d = 0.0;
        for b in 0..4 {
            for j in 0..64 {
                let mut exact = 0.0f64;
                for i in 0..128 {
                    exact += x.at(b, i) as f64 * w.at(i, j) as f64;
                }
                err_a += (ya.at(b, j) as f64 - exact).powi(2);
                err_d += (yd.at(b, j) as f64 - exact).powi(2);
            }
        }
        assert!(err_d < err_a, "digital {err_d} analog {err_a}");
    }

    #[test]
    fn quantize_bounds_property() {
        miniprop::check("weights-bounded", 0xB2, |rng| {
            let scale = 1.0 + rng.next_f32() * 10.0;
            let w = rand_matrix(rng, 8, 8, scale);
            let (w_q, scale) = quantize_weights(&w);
            assert!(scale > 0.0);
            for v in &w_q.data {
                assert!(v.abs() <= WEIGHT_LEVELS);
                assert_eq!(*v, v.round());
            }
        });
    }

    #[test]
    fn batch_rows_independent_property() {
        miniprop::check("batch-independent", 0xC3, |rng| {
            let m = 16 + rng.below(48) as usize;
            let n = 8 + rng.below(24) as usize;
            let x = rand_matrix(rng, 3, m, 1.0);
            let w = rand_matrix(rng, m, n, 0.2);
            let (w_q, _) = quantize_weights(&w);
            let spec = calibrate(&x, &w, 16, n);
            let full = aimc_mvm(&x, &w_q, &spec);
            for b in 0..3 {
                let row = Matrix::new(1, m, x.data[b * m..(b + 1) * m].to_vec());
                let single = aimc_mvm(&row, &w_q, &spec);
                for j in 0..n {
                    assert_eq!(full.at(b, j), single.at(0, j));
                }
            }
        });
    }

    #[test]
    fn adc_saturation_bounds_output() {
        let mut rng = Rng::new(9);
        let x = rand_matrix(&mut rng, 1, 64, 1.0);
        let w = rand_matrix(&mut rng, 64, 16, 0.1);
        let (w_q, _) = quantize_weights(&w);
        let spec = calibrate(&x, &w, 64, 16);
        // Drive far past the calibrated range.
        let x_hot = Matrix::new(1, 64, x.data.iter().map(|v| v * 1000.0).collect());
        let y = aimc_mvm(&x_hot, &w_q, &spec);
        let bound = 128.0 * spec.adc_scale * spec.in_scale * spec.w_scale * 1.001;
        for v in &y.data {
            assert!(v.abs() <= bound);
        }
    }

    #[test]
    fn program_weights_deterministic() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let w = rand_matrix(&mut Rng::new(0), 8, 8, 1.0);
        let (wq, _) = quantize_weights(&w);
        let a = program_weights(&wq, 0.02, &mut r1);
        let b = program_weights(&wq, 0.02, &mut r2);
        assert_eq!(a.data, b.data);
    }
}
