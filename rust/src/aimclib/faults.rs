//! Device-level fault injection for the AIMClib checker (paper §IV.C
//! plus the PCM non-idealities of Le Gallo et al. and Garofalo et al.,
//! PAPERS.md): Gaussian conductance noise, time-parameterized
//! conductance drift, and stuck-at rows/columns, all derived
//! deterministically from one seed so every run is reproducible.
//!
//! A [`FaultPlan`] perturbs the *programmed* weight codes the checker
//! would put on a crossbar; [`assess_mvm`] then measures the accuracy
//! proxy of the perturbed tile against the fault-free checker (output
//! MSE and top-1 agreement). `FaultPlan::none()` (the default) applies
//! nothing and returns the weights untouched — the fault-free path is
//! bit-identical.

use crate::aimclib::checker::{aimc_mvm, calibrate, quantize_weights, Matrix, WEIGHT_LEVELS};
use crate::util::rng::Rng;

/// Reference time of the drift law: conductances are calibrated one
/// second after programming (Le Gallo et al.), so `drift_t_s <= 1`
/// means "no observable drift yet".
const DRIFT_T0_S: f64 = 1.0;

/// Picoseconds per second — the machine's virtual clock runs in ps.
const PS_PER_S: f64 = 1.0e12;

/// Iterative program-and-verify time per word line (PCM cells are
/// programmed one row at a time; Le Gallo et al. report µs-scale
/// multi-pulse sequences per line).
pub const PROGRAM_ROW_S: f64 = 1.0e-6;

/// Program energy per cell (SET/RESET pulse train, ~100 pJ for PCM).
pub const PROGRAM_CELL_J: f64 = 100.0e-12;

/// Closed-form conductance decay of the drift law at age `t_s` seconds
/// since programming: `(t/t0)^-nu`, 1.0 when disabled (`nu <= 0`) or
/// not yet observable (`t_s <= t0`). Shared by [`FaultPlan`] and the
/// simulator tile health sensor so both layers report the same physics.
pub fn drift_decay(t_s: f64, nu: f64) -> f64 {
    if nu <= 0.0 || t_s <= DRIFT_T0_S {
        return 1.0;
    }
    (t_s / DRIFT_T0_S).powf(-nu)
}

/// Seed-driven device fault plan. All rates are intensities in `[0, 1]`
/// (or physical units where noted); every field at its default disables
/// that fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed of the per-plan RNG stream (noise draws, stuck-line picks).
    pub seed: u64,
    /// Gaussian conductance-programming noise, sigma relative to the
    /// full conductance range (`sigma * WEIGHT_LEVELS` in code units).
    pub noise_sigma: f32,
    /// Observation time since programming, seconds; PCM conductances
    /// decay as `G(t) = G(t0) * (t/t0)^-nu`.
    pub drift_t_s: f64,
    /// Drift exponent nu (~0.05 for PCM; 0 disables drift).
    pub drift_nu: f64,
    /// Fraction of word lines (rows) stuck at a fixed conductance.
    pub stuck_row_rate: f64,
    /// Fraction of bit lines (columns) stuck at a fixed conductance.
    pub stuck_col_rate: f64,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan {
            seed: 0,
            noise_sigma: 0.0,
            drift_t_s: 0.0,
            drift_nu: 0.0,
            stuck_row_rate: 0.0,
            stuck_col_rate: 0.0,
        }
    }
}

/// Accuracy proxy of a faulted tile vs the fault-free checker.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultImpact {
    /// Mean squared output error over all batch rows and columns.
    pub mse: f64,
    /// Fraction of batch rows whose argmax output column agrees with
    /// the fault-free checker (1.0 = no classification-level impact).
    pub top1_agreement: f64,
    /// Number of outputs compared (batch * cols).
    pub outputs: usize,
}

impl FaultPlan {
    /// The fault-free plan: `apply` is the identity.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_none(&self) -> bool {
        self.noise_sigma <= 0.0
            && (self.drift_nu <= 0.0 || self.drift_t_s <= DRIFT_T0_S)
            && self.stuck_row_rate <= 0.0
            && self.stuck_col_rate <= 0.0
    }

    /// Multiplicative conductance decay factor of the drift law at
    /// `drift_t_s` (1.0 when drift is disabled or not yet observable).
    pub fn drift_factor(&self) -> f64 {
        drift_decay(self.drift_t_s, self.drift_nu)
    }

    /// Perturb programmed weight codes: drift decay, then Gaussian
    /// programming noise, then stuck rows/columns (a stuck line
    /// overrides everything else on it). Deterministic in `seed`;
    /// `none()` returns a verbatim clone.
    pub fn apply(&self, w_prog: &Matrix) -> Matrix {
        if self.is_none() {
            return w_prog.clone();
        }
        let mut rng = Rng::new(self.seed);
        let mut out = w_prog.clone();
        let decay = self.drift_factor() as f32;
        if decay < 1.0 {
            for v in &mut out.data {
                *v *= decay;
            }
        }
        if self.noise_sigma > 0.0 {
            for v in &mut out.data {
                *v += rng.normal_f32(self.noise_sigma * WEIGHT_LEVELS);
            }
        }
        // Stuck lines: a pick per line keeps the RNG stream length
        // independent of the rates, so raising one knob never re-seeds
        // the draws of another.
        for r in 0..out.rows {
            let hit = rng.next_f64() < self.stuck_row_rate;
            let stuck = if rng.below(2) == 0 { 0.0 } else { WEIGHT_LEVELS };
            if hit {
                for c in 0..out.cols {
                    out.data[r * out.cols + c] = stuck;
                }
            }
        }
        for c in 0..out.cols {
            let hit = rng.next_f64() < self.stuck_col_rate;
            let stuck = if rng.below(2) == 0 { 0.0 } else { -WEIGHT_LEVELS };
            if hit {
                for r in 0..out.rows {
                    out.data[r * out.cols + c] = stuck;
                }
            }
        }
        out
    }
}

/// Per-tile drift state keyed on the *programming timestamp* of the
/// machine's virtual clock, so `G(t) = G(t0) * (t/t0)^-nu` (and the
/// [`assess_mvm`] accuracy proxy derived from it) are functions of
/// virtual time rather than a fixed intensity knob. Reprogramming
/// resets the timestamp at the modeled [`reprogram_cost`].
///
/// Two physical effects age a tile (Le Gallo et al.):
/// - the mean conductance decays by `(t/t0)^-nu`;
/// - per-device dispersion of `nu` spreads the decay, which the plan
///   models as Gaussian programming noise growing as
///   `nu_sigma * ln(t/t0)` — this is what eventually breaks argmax
///   agreement, since a *uniform* decay alone rescales every output.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DriftState {
    /// Virtual-time programming timestamp t0, picoseconds.
    pub programmed_at_ps: u64,
    /// Mean drift exponent nu (~0.05 for PCM; 0 disables drift).
    pub nu: f64,
    /// Per-device nu dispersion: the plan's noise sigma at age t is
    /// `nu_sigma * ln(t/t0)` (0 disables the stochastic component).
    pub nu_sigma: f64,
    /// Seed of the derived plan's RNG stream.
    pub seed: u64,
}

impl DriftState {
    /// A tile programmed at virtual time zero.
    pub fn new(seed: u64, nu: f64, nu_sigma: f64) -> DriftState {
        DriftState { programmed_at_ps: 0, nu, nu_sigma, seed }
    }

    /// Seconds since programming at virtual time `now_ps` (0 when the
    /// clock has not reached the programming timestamp yet).
    pub fn age_s(&self, now_ps: u64) -> f64 {
        now_ps.saturating_sub(self.programmed_at_ps) as f64 / PS_PER_S
    }

    /// Mean conductance decay factor at virtual time `now_ps`.
    pub fn drift_factor_at(&self, now_ps: u64) -> f64 {
        drift_decay(self.age_s(now_ps), self.nu)
    }

    /// The [`FaultPlan`] this tile's age implies at virtual time
    /// `now_ps`: time-parameterized decay plus log-time-growing noise.
    /// Fresh tiles (age <= t0) yield `FaultPlan::none()`.
    pub fn plan_at(&self, now_ps: u64) -> FaultPlan {
        let age = self.age_s(now_ps);
        if age <= DRIFT_T0_S {
            return FaultPlan { seed: self.seed, ..FaultPlan::none() };
        }
        FaultPlan {
            seed: self.seed,
            noise_sigma: (self.nu_sigma * (age / DRIFT_T0_S).ln()).max(0.0) as f32,
            drift_t_s: age,
            drift_nu: self.nu,
            ..FaultPlan::none()
        }
    }

    /// Accuracy proxy of this tile at virtual time `now_ps` (see
    /// [`assess_mvm`]).
    pub fn assess_at(
        &self,
        now_ps: u64,
        rows: usize,
        cols: usize,
        tile_rows: usize,
        tile_cols: usize,
        batch: usize,
    ) -> FaultImpact {
        assess_mvm(&self.plan_at(now_ps), rows, cols, tile_rows, tile_cols, batch)
    }

    /// Reprogram the tile at virtual time `now_ps`: resets t0 so the
    /// drift clock restarts. The time/energy price is modeled by
    /// [`reprogram_cost`]; charging it is the caller's job (the serving
    /// layer books it as replica downtime).
    pub fn reprogram(&mut self, now_ps: u64) {
        self.programmed_at_ps = now_ps;
    }
}

/// Modeled cost of reprogramming (refreshing) a crossbar tile.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReprogramCost {
    /// Wall time of the program-and-verify sequence, seconds.
    pub time_s: f64,
    /// Total program pulse energy, joules.
    pub energy_j: f64,
}

/// Price of refreshing a `rows x cols` tile: rows are programmed one
/// word line at a time ([`PROGRAM_ROW_S`]), every cell takes a pulse
/// train ([`PROGRAM_CELL_J`]).
pub fn reprogram_cost(rows: usize, cols: usize) -> ReprogramCost {
    ReprogramCost {
        time_s: rows as f64 * PROGRAM_ROW_S,
        energy_j: (rows * cols) as f64 * PROGRAM_CELL_J,
    }
}

/// Compare a faulted analog MVM against the fault-free checker on a
/// deterministic synthetic layer: `rows x cols` Gaussian weights and a
/// `batch`-row probe input, both derived from the plan's seed. Returns
/// the accuracy proxy (output MSE + top-1 agreement).
pub fn assess_mvm(
    plan: &FaultPlan,
    rows: usize,
    cols: usize,
    tile_rows: usize,
    tile_cols: usize,
    batch: usize,
) -> FaultImpact {
    // Probe data comes from a stream decoupled from the plan's own draw
    // stream (`apply` re-seeds internally), keyed so the same layer
    // shape probes identically across fault intensities.
    let mut rng = Rng::new(plan.seed ^ 0x5EED_F00D);
    let x = Matrix::new(batch, rows, (0..batch * rows).map(|_| rng.normal_f32(1.0)).collect());
    let w = Matrix::new(rows, cols, (0..rows * cols).map(|_| rng.normal_f32(0.1)).collect());
    let spec = calibrate(&x, &w, tile_rows, tile_cols);
    let (w_q, _) = quantize_weights(&w);
    let clean = aimc_mvm(&x, &w_q, &spec);
    let faulty = aimc_mvm(&x, &plan.apply(&w_q), &spec);

    let n = clean.data.len();
    let mut se = 0.0f64;
    for (a, b) in faulty.data.iter().zip(&clean.data) {
        let d = (*a - *b) as f64;
        se += d * d;
    }
    let argmax = |m: &Matrix, b: usize| -> usize {
        let row = &m.data[b * m.cols..(b + 1) * m.cols];
        let mut best = 0;
        for (j, v) in row.iter().enumerate() {
            if *v > row[best] {
                best = j;
            }
        }
        best
    };
    let agree = (0..batch).filter(|&b| argmax(&faulty, b) == argmax(&clean, b)).count();
    FaultImpact {
        mse: se / n as f64,
        top1_agreement: agree as f64 / batch as f64,
        outputs: n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::miniprop;

    fn probe_matrix(seed: u64, rows: usize, cols: usize) -> Matrix {
        let mut rng = Rng::new(seed);
        let w = Matrix::new(rows, cols, (0..rows * cols).map(|_| rng.normal_f32(0.1)).collect());
        quantize_weights(&w).0
    }

    #[test]
    fn none_plan_is_identity() {
        let w = probe_matrix(7, 24, 16);
        let plan = FaultPlan::none();
        assert!(plan.is_none());
        assert_eq!(plan.apply(&w).data, w.data);
        let impact = assess_mvm(&plan, 32, 16, 32, 16, 8);
        assert_eq!(impact.mse, 0.0);
        assert_eq!(impact.top1_agreement, 1.0);
    }

    #[test]
    fn apply_is_deterministic_in_seed() {
        let w = probe_matrix(3, 32, 24);
        let plan = FaultPlan {
            seed: 42,
            noise_sigma: 0.05,
            stuck_row_rate: 0.1,
            stuck_col_rate: 0.05,
            ..FaultPlan::none()
        };
        assert_eq!(plan.apply(&w).data, plan.apply(&w).data);
        let other = FaultPlan { seed: 43, ..plan };
        assert_ne!(other.apply(&w).data, plan.apply(&w).data);
    }

    #[test]
    fn drift_decays_conductance_magnitude() {
        let w = probe_matrix(5, 16, 16);
        let plan = FaultPlan { seed: 1, drift_t_s: 1.0e6, drift_nu: 0.05, ..FaultPlan::none() };
        assert!(plan.drift_factor() < 1.0);
        let drifted = plan.apply(&w);
        for (d, o) in drifted.data.iter().zip(&w.data) {
            assert!(d.abs() <= o.abs() + 1e-6, "{d} vs {o}");
        }
        // Not yet observable at the calibration time.
        let fresh = FaultPlan { drift_t_s: 1.0, ..plan };
        assert_eq!(fresh.drift_factor(), 1.0);
        assert!(fresh.is_none());
    }

    #[test]
    fn stuck_lines_override_everything() {
        let w = probe_matrix(9, 20, 12);
        let plan = FaultPlan { seed: 2, stuck_row_rate: 1.0, ..FaultPlan::none() };
        let out = plan.apply(&w);
        for r in 0..out.rows {
            let first = out.at(r, 0);
            assert!(first == 0.0 || first == WEIGHT_LEVELS);
            for c in 0..out.cols {
                assert_eq!(out.at(r, c), first, "row {r} not uniformly stuck");
            }
        }
    }

    #[test]
    fn accuracy_proxy_degrades_with_intensity() {
        let mk = |sigma: f32, stuck: f64| FaultPlan {
            seed: 11,
            noise_sigma: sigma,
            stuck_row_rate: stuck,
            stuck_col_rate: stuck,
            ..FaultPlan::none()
        };
        let mild = assess_mvm(&mk(0.01, 0.0), 64, 32, 64, 32, 16);
        let severe = assess_mvm(&mk(0.2, 0.3), 64, 32, 64, 32, 16);
        assert!(mild.mse > 0.0);
        assert!(severe.mse > mild.mse, "mild {} severe {}", mild.mse, severe.mse);
        assert!(severe.top1_agreement <= mild.top1_agreement);
        assert!(severe.top1_agreement < 1.0);
    }

    #[test]
    fn drift_state_ages_with_virtual_time_and_reprogram_resets_it() {
        const S: u64 = 1_000_000_000_000; // 1 s in ps
        let mut d = DriftState::new(77, 0.05, 0.01);
        // Fresh: within t0 the derived plan is the identity.
        assert!(d.plan_at(S / 2).is_none());
        assert_eq!(d.drift_factor_at(S / 2), 1.0);
        // Aged: decay < 1 and noise grows with log-age.
        let old = d.plan_at(1_000_000 * S);
        assert!(old.drift_factor() < 1.0);
        assert!(old.noise_sigma > 0.0);
        let older = d.plan_at(10_000_000 * S);
        assert!(older.drift_factor() < old.drift_factor());
        assert!(older.noise_sigma > old.noise_sigma);
        // Age is relative to t0, not absolute time.
        d.reprogram(1_000_000 * S);
        assert!(d.plan_at(1_000_000 * S).is_none());
        assert_eq!(d.age_s(1_000_000 * S), 0.0);
        let rejuvenated = d.plan_at(1_001_000 * S);
        assert_eq!(rejuvenated.drift_t_s, 1_000.0);
        assert!(rejuvenated.drift_factor() > old.drift_factor());
    }

    #[test]
    fn drift_state_accuracy_proxy_degrades_with_age() {
        const S: u64 = 1_000_000_000_000;
        let d = DriftState::new(13, 0.05, 0.02);
        let fresh = d.assess_at(0, 64, 32, 64, 32, 16);
        assert_eq!(fresh.mse, 0.0);
        assert_eq!(fresh.top1_agreement, 1.0);
        let aged = d.assess_at(100_000_000 * S, 64, 32, 64, 32, 16);
        assert!(aged.mse > 0.0);
        assert!(aged.top1_agreement < 1.0, "top1 {}", aged.top1_agreement);
    }

    #[test]
    fn reprogram_cost_scales_with_tile_dims() {
        let small = reprogram_cost(64, 64);
        let big = reprogram_cost(256, 256);
        assert_eq!(small.time_s, 64.0 * PROGRAM_ROW_S);
        assert_eq!(big.energy_j, 256.0 * 256.0 * PROGRAM_CELL_J);
        assert!(big.time_s > small.time_s && big.energy_j > small.energy_j);
    }

    #[test]
    fn rng_stream_stable_across_rate_changes() {
        // Raising the stuck-row rate must not change *which* noise is
        // drawn (per-line picks are always consumed).
        miniprop::check("faults/stream-stable", 0xFA_017, |rng| {
            let rows = 4 + rng.below(12) as usize;
            let cols = 4 + rng.below(12) as usize;
            let w = probe_matrix(rng.next_u64(), rows, cols);
            let seed = rng.next_u64();
            let a = FaultPlan { seed, noise_sigma: 0.05, ..FaultPlan::none() };
            let b = FaultPlan { seed, noise_sigma: 0.05, stuck_col_rate: 1.0, ..FaultPlan::none() };
            let wa = a.apply(&w);
            let wb = b.apply(&w);
            // Columns are all stuck in b, but the noise component that
            // preceded the stuck pass was drawn identically: recompute a
            // with the same seed and compare where b is not stuck — here
            // everything is stuck, so just check determinism of a.
            assert_eq!(wa.data, a.apply(&w).data);
            assert_eq!(wb.data, b.apply(&w).data);
        });
    }
}
