//! Device-level fault injection for the AIMClib checker (paper §IV.C
//! plus the PCM non-idealities of Le Gallo et al. and Garofalo et al.,
//! PAPERS.md): Gaussian conductance noise, time-parameterized
//! conductance drift, and stuck-at rows/columns, all derived
//! deterministically from one seed so every run is reproducible.
//!
//! A [`FaultPlan`] perturbs the *programmed* weight codes the checker
//! would put on a crossbar; [`assess_mvm`] then measures the accuracy
//! proxy of the perturbed tile against the fault-free checker (output
//! MSE and top-1 agreement). `FaultPlan::none()` (the default) applies
//! nothing and returns the weights untouched — the fault-free path is
//! bit-identical.

use crate::aimclib::checker::{aimc_mvm, calibrate, quantize_weights, Matrix, WEIGHT_LEVELS};
use crate::util::rng::Rng;

/// Reference time of the drift law: conductances are calibrated one
/// second after programming (Le Gallo et al.), so `drift_t_s <= 1`
/// means "no observable drift yet".
const DRIFT_T0_S: f64 = 1.0;

/// Seed-driven device fault plan. All rates are intensities in `[0, 1]`
/// (or physical units where noted); every field at its default disables
/// that fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed of the per-plan RNG stream (noise draws, stuck-line picks).
    pub seed: u64,
    /// Gaussian conductance-programming noise, sigma relative to the
    /// full conductance range (`sigma * WEIGHT_LEVELS` in code units).
    pub noise_sigma: f32,
    /// Observation time since programming, seconds; PCM conductances
    /// decay as `G(t) = G(t0) * (t/t0)^-nu`.
    pub drift_t_s: f64,
    /// Drift exponent nu (~0.05 for PCM; 0 disables drift).
    pub drift_nu: f64,
    /// Fraction of word lines (rows) stuck at a fixed conductance.
    pub stuck_row_rate: f64,
    /// Fraction of bit lines (columns) stuck at a fixed conductance.
    pub stuck_col_rate: f64,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan {
            seed: 0,
            noise_sigma: 0.0,
            drift_t_s: 0.0,
            drift_nu: 0.0,
            stuck_row_rate: 0.0,
            stuck_col_rate: 0.0,
        }
    }
}

/// Accuracy proxy of a faulted tile vs the fault-free checker.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultImpact {
    /// Mean squared output error over all batch rows and columns.
    pub mse: f64,
    /// Fraction of batch rows whose argmax output column agrees with
    /// the fault-free checker (1.0 = no classification-level impact).
    pub top1_agreement: f64,
    /// Number of outputs compared (batch * cols).
    pub outputs: usize,
}

impl FaultPlan {
    /// The fault-free plan: `apply` is the identity.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_none(&self) -> bool {
        self.noise_sigma <= 0.0
            && (self.drift_nu <= 0.0 || self.drift_t_s <= DRIFT_T0_S)
            && self.stuck_row_rate <= 0.0
            && self.stuck_col_rate <= 0.0
    }

    /// Multiplicative conductance decay factor of the drift law at
    /// `drift_t_s` (1.0 when drift is disabled or not yet observable).
    pub fn drift_factor(&self) -> f64 {
        if self.drift_nu <= 0.0 || self.drift_t_s <= DRIFT_T0_S {
            return 1.0;
        }
        (self.drift_t_s / DRIFT_T0_S).powf(-self.drift_nu)
    }

    /// Perturb programmed weight codes: drift decay, then Gaussian
    /// programming noise, then stuck rows/columns (a stuck line
    /// overrides everything else on it). Deterministic in `seed`;
    /// `none()` returns a verbatim clone.
    pub fn apply(&self, w_prog: &Matrix) -> Matrix {
        if self.is_none() {
            return w_prog.clone();
        }
        let mut rng = Rng::new(self.seed);
        let mut out = w_prog.clone();
        let decay = self.drift_factor() as f32;
        if decay < 1.0 {
            for v in &mut out.data {
                *v *= decay;
            }
        }
        if self.noise_sigma > 0.0 {
            for v in &mut out.data {
                *v += rng.normal_f32(self.noise_sigma * WEIGHT_LEVELS);
            }
        }
        // Stuck lines: a pick per line keeps the RNG stream length
        // independent of the rates, so raising one knob never re-seeds
        // the draws of another.
        for r in 0..out.rows {
            let hit = rng.next_f64() < self.stuck_row_rate;
            let stuck = if rng.below(2) == 0 { 0.0 } else { WEIGHT_LEVELS };
            if hit {
                for c in 0..out.cols {
                    out.data[r * out.cols + c] = stuck;
                }
            }
        }
        for c in 0..out.cols {
            let hit = rng.next_f64() < self.stuck_col_rate;
            let stuck = if rng.below(2) == 0 { 0.0 } else { -WEIGHT_LEVELS };
            if hit {
                for r in 0..out.rows {
                    out.data[r * out.cols + c] = stuck;
                }
            }
        }
        out
    }
}

/// Compare a faulted analog MVM against the fault-free checker on a
/// deterministic synthetic layer: `rows x cols` Gaussian weights and a
/// `batch`-row probe input, both derived from the plan's seed. Returns
/// the accuracy proxy (output MSE + top-1 agreement).
pub fn assess_mvm(
    plan: &FaultPlan,
    rows: usize,
    cols: usize,
    tile_rows: usize,
    tile_cols: usize,
    batch: usize,
) -> FaultImpact {
    // Probe data comes from a stream decoupled from the plan's own draw
    // stream (`apply` re-seeds internally), keyed so the same layer
    // shape probes identically across fault intensities.
    let mut rng = Rng::new(plan.seed ^ 0x5EED_F00D);
    let x = Matrix::new(batch, rows, (0..batch * rows).map(|_| rng.normal_f32(1.0)).collect());
    let w = Matrix::new(rows, cols, (0..rows * cols).map(|_| rng.normal_f32(0.1)).collect());
    let spec = calibrate(&x, &w, tile_rows, tile_cols);
    let (w_q, _) = quantize_weights(&w);
    let clean = aimc_mvm(&x, &w_q, &spec);
    let faulty = aimc_mvm(&x, &plan.apply(&w_q), &spec);

    let n = clean.data.len();
    let mut se = 0.0f64;
    for (a, b) in faulty.data.iter().zip(&clean.data) {
        let d = (*a - *b) as f64;
        se += d * d;
    }
    let argmax = |m: &Matrix, b: usize| -> usize {
        let row = &m.data[b * m.cols..(b + 1) * m.cols];
        let mut best = 0;
        for (j, v) in row.iter().enumerate() {
            if *v > row[best] {
                best = j;
            }
        }
        best
    };
    let agree = (0..batch).filter(|&b| argmax(&faulty, b) == argmax(&clean, b)).count();
    FaultImpact {
        mse: se / n as f64,
        top1_agreement: agree as f64 / batch as f64,
        outputs: n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::miniprop;

    fn probe_matrix(seed: u64, rows: usize, cols: usize) -> Matrix {
        let mut rng = Rng::new(seed);
        let w = Matrix::new(rows, cols, (0..rows * cols).map(|_| rng.normal_f32(0.1)).collect());
        quantize_weights(&w).0
    }

    #[test]
    fn none_plan_is_identity() {
        let w = probe_matrix(7, 24, 16);
        let plan = FaultPlan::none();
        assert!(plan.is_none());
        assert_eq!(plan.apply(&w).data, w.data);
        let impact = assess_mvm(&plan, 32, 16, 32, 16, 8);
        assert_eq!(impact.mse, 0.0);
        assert_eq!(impact.top1_agreement, 1.0);
    }

    #[test]
    fn apply_is_deterministic_in_seed() {
        let w = probe_matrix(3, 32, 24);
        let plan = FaultPlan {
            seed: 42,
            noise_sigma: 0.05,
            stuck_row_rate: 0.1,
            stuck_col_rate: 0.05,
            ..FaultPlan::none()
        };
        assert_eq!(plan.apply(&w).data, plan.apply(&w).data);
        let other = FaultPlan { seed: 43, ..plan };
        assert_ne!(other.apply(&w).data, plan.apply(&w).data);
    }

    #[test]
    fn drift_decays_conductance_magnitude() {
        let w = probe_matrix(5, 16, 16);
        let plan = FaultPlan { seed: 1, drift_t_s: 1.0e6, drift_nu: 0.05, ..FaultPlan::none() };
        assert!(plan.drift_factor() < 1.0);
        let drifted = plan.apply(&w);
        for (d, o) in drifted.data.iter().zip(&w.data) {
            assert!(d.abs() <= o.abs() + 1e-6, "{d} vs {o}");
        }
        // Not yet observable at the calibration time.
        let fresh = FaultPlan { drift_t_s: 1.0, ..plan };
        assert_eq!(fresh.drift_factor(), 1.0);
        assert!(fresh.is_none());
    }

    #[test]
    fn stuck_lines_override_everything() {
        let w = probe_matrix(9, 20, 12);
        let plan = FaultPlan { seed: 2, stuck_row_rate: 1.0, ..FaultPlan::none() };
        let out = plan.apply(&w);
        for r in 0..out.rows {
            let first = out.at(r, 0);
            assert!(first == 0.0 || first == WEIGHT_LEVELS);
            for c in 0..out.cols {
                assert_eq!(out.at(r, c), first, "row {r} not uniformly stuck");
            }
        }
    }

    #[test]
    fn accuracy_proxy_degrades_with_intensity() {
        let mk = |sigma: f32, stuck: f64| FaultPlan {
            seed: 11,
            noise_sigma: sigma,
            stuck_row_rate: stuck,
            stuck_col_rate: stuck,
            ..FaultPlan::none()
        };
        let mild = assess_mvm(&mk(0.01, 0.0), 64, 32, 64, 32, 16);
        let severe = assess_mvm(&mk(0.2, 0.3), 64, 32, 64, 32, 16);
        assert!(mild.mse > 0.0);
        assert!(severe.mse > mild.mse, "mild {} severe {}", mild.mse, severe.mse);
        assert!(severe.top1_agreement <= mild.top1_agreement);
        assert!(severe.top1_agreement < 1.0);
    }

    #[test]
    fn rng_stream_stable_across_rate_changes() {
        // Raising the stuck-row rate must not change *which* noise is
        // drawn (per-line picks are always consumed).
        miniprop::check("faults/stream-stable", 0xFA_017, |rng| {
            let rows = 4 + rng.below(12) as usize;
            let cols = 4 + rng.below(12) as usize;
            let w = probe_matrix(rng.next_u64(), rows, cols);
            let seed = rng.next_u64();
            let a = FaultPlan { seed, noise_sigma: 0.05, ..FaultPlan::none() };
            let b = FaultPlan { seed, noise_sigma: 0.05, stuck_col_rate: 1.0, ..FaultPlan::none() };
            let wa = a.apply(&w);
            let wb = b.apply(&w);
            // Columns are all stuck in b, but the noise component that
            // preceded the stuck pass was drawn identically: recompute a
            // with the same seed and compare where b is not stuck — here
            // everything is stuck, so just check determinism of a.
            assert_eq!(wa.data, a.apply(&w).data);
            assert_eq!(wb.data, b.apply(&w).data);
        });
    }
}
