//! # ALPINE — Analog In-Memory Acceleration with Tight Processor Integration
//!
//! A full reproduction of Klein et al., *"ALPINE: Analog In-Memory
//! Acceleration with Tight Processor Integration for Deep Learning"*
//! (IEEE TC 2022), as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the ALPINE full-system simulation
//!   framework: an event-driven multi-core timing model with caches,
//!   DRAM, buses and AIMC tiles ([`sim`]), the CM_* ISA extension
//!   ([`isa`]), the AIMClib software library ([`aimclib`]), workload
//!   generators for the paper's MLP/LSTM/CNN explorations ([`workload`]),
//!   the Table-I energy model ([`energy`]), and the experiment
//!   coordinator that regenerates every figure ([`coordinator`]).
//! * **Layer 2/1 (build-time Python)** — JAX models + the Pallas AIMC
//!   crossbar kernel, AOT-lowered to HLO text and executed from Rust via
//!   PJRT ([`runtime`]). Python never runs on the request path.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod aimclib;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod isa;
pub mod nn;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod util;
pub mod workload;

/// The one-import surface of the end-to-end flow: build a graph
/// ([`nn::LayerGraph`] / [`nn::GraphBuilder`]), map it ([`workload::automap`]
/// or a hand-written [`workload::compile::mapping::Mapping`]), compile it
/// ([`workload::compile`]), and simulate it
/// ([`coordinator::run_workload`] under [`coordinator::RunOptions`],
/// optionally perturbed by an [`aimclib::faults::FaultPlan`]).
///
/// ```no_run
/// use alpine::prelude::*;
///
/// let graph = LayerGraph::resnet_block(8, 4, 10);
/// let cfg = SystemConfig::high_power();
/// let budget = TopologyBudget::for_config(&cfg);
/// let out = search(&graph, &budget, &cfg, 4).unwrap();
/// let w = compile(&graph, &out.ranked[0].mapping, 5).unwrap();
/// let r = run_workload(SystemKind::HighPower, w, &RunOptions::default()).unwrap();
/// println!("{}: {:.3} us/inf", graph.name, r.time_per_inference_s * 1e6);
/// ```
pub mod prelude {
    pub use crate::aimclib::faults::FaultPlan;
    pub use crate::config::{SystemConfig, SystemKind};
    pub use crate::coordinator::serving::{
        run_serve_bench, ArrivalProcess, Backend, RouterPolicy, ServeBenchOptions,
    };
    pub use crate::coordinator::{run_workload, CaseResult, RunOptions};
    pub use crate::nn::{
        ActKind, GraphBuilder, GraphError, LayerGraph, LayerKind, MergeOp, NodeId,
    };
    pub use crate::sim::{RunError, TileFaultModel};
    pub use crate::workload::automap::{
        search, search_opts, SearchOptions, TopologyBudget,
    };
    pub use crate::workload::compile::{compile, validate};
    pub use crate::workload::compile::mapping::Mapping;
    pub use crate::workload::{Workload, WorkloadError};
}
