//! # ALPINE — Analog In-Memory Acceleration with Tight Processor Integration
//!
//! A full reproduction of Klein et al., *"ALPINE: Analog In-Memory
//! Acceleration with Tight Processor Integration for Deep Learning"*
//! (IEEE TC 2022), as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the ALPINE full-system simulation
//!   framework: an event-driven multi-core timing model with caches,
//!   DRAM, buses and AIMC tiles ([`sim`]), the CM_* ISA extension
//!   ([`isa`]), the AIMClib software library ([`aimclib`]), workload
//!   generators for the paper's MLP/LSTM/CNN explorations ([`workload`]),
//!   the Table-I energy model ([`energy`]), and the experiment
//!   coordinator that regenerates every figure ([`coordinator`]).
//! * **Layer 2/1 (build-time Python)** — JAX models + the Pallas AIMC
//!   crossbar kernel, AOT-lowered to HLO text and executed from Rust via
//!   PJRT ([`runtime`]). Python never runs on the request path.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod aimclib;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod isa;
pub mod nn;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod util;
pub mod workload;
