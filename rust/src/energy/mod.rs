//! Full-system energy computation (paper §VI.A): "the full system energy
//! is the sum of the energies for the core, cache, and DRAM components",
//! computed from the gem5-X-style statistics — plus the AIMC tile energy
//! (Table I-C, already accumulated per-operation by the device model).

use crate::config::SystemConfig;
use crate::stats::RunStats;

/// Energy breakdown of one run, joules.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyBreakdown {
    pub core_active_j: f64,
    pub core_wfm_j: f64,
    pub core_idle_j: f64,
    pub llc_dynamic_j: f64,
    pub llc_leakage_j: f64,
    pub dram_j: f64,
    pub mem_ctrl_io_j: f64,
    pub aimc_j: f64,
}

impl EnergyBreakdown {
    pub fn core_total_j(&self) -> f64 {
        self.core_active_j + self.core_wfm_j + self.core_idle_j
    }

    pub fn total_j(&self) -> f64 {
        self.core_total_j()
            + self.llc_dynamic_j
            + self.llc_leakage_j
            + self.dram_j
            + self.mem_ctrl_io_j
            + self.aimc_j
    }
}

/// Compute the Table I-B energy for a finished run.
///
/// Note on idle cores: the paper's 8-core systems always power all
/// cores; cores not used by a mapping sit idle for the whole ROI and
/// contribute idle energy (this is why single-core analog MLP mappings
/// also win on energy — they finish sooner, shortening everyone's idle
/// window).
pub fn compute(cfg: &SystemConfig, stats: &RunStats) -> EnergyBreakdown {
    let p = &cfg.power;
    let t = stats.roi_time_s();
    let total_cycles_per_core = (stats.roi_time_ps / cfg.cycle_ps()).max(1);

    let mut e = EnergyBreakdown::default();

    // Cores that ran traces.
    let mut used = 0usize;
    for c in &stats.cores {
        e.core_active_j += c.active_cycles as f64 * p.active_core_j_per_cycle;
        e.core_wfm_j += c.wfm_cycles as f64 * p.wfm_core_j_per_cycle;
        e.core_idle_j += c.idle_cycles as f64 * p.idle_core_j_per_cycle;
        used += 1;
    }
    // Unused cores idle for the full ROI.
    let unused = cfg.num_cores.saturating_sub(used);
    e.core_idle_j +=
        unused as f64 * total_cycles_per_core as f64 * p.idle_core_j_per_cycle;

    e.llc_dynamic_j = stats.llc_bytes_read as f64 * p.llc_read_j_per_byte
        + stats.llc_bytes_written as f64 * p.llc_write_j_per_byte;
    e.llc_leakage_j = p.llc_leakage_w(cfg.llc.size_bytes) * t;
    e.dram_j = stats.dram_accesses as f64 * p.dram_j_per_access;
    e.mem_ctrl_io_j = p.mem_ctrl_io_w * t;
    e.aimc_j = stats.aimc.energy_j;
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{CoreStats, RunStats};

    fn stats_one_core(active: u64, wfm: u64, idle: u64, time_ps: u64) -> RunStats {
        let mut rs = RunStats::new(1);
        rs.cores[0] = CoreStats { insts: active, active_cycles: active, wfm_cycles: wfm, idle_cycles: idle };
        rs.roi_time_ps = time_ps;
        rs
    }

    #[test]
    fn core_energy_uses_state_rates() {
        let cfg = SystemConfig::high_power();
        let rs = stats_one_core(1000, 500, 200, 435 * 1700);
        let e = compute(&cfg, &rs);
        let expect_active = 1000.0 * 845.39e-12;
        let expect_wfm = 500.0 * 638.99e-12;
        assert!((e.core_active_j - expect_active).abs() < 1e-15);
        assert!((e.core_wfm_j - expect_wfm).abs() < 1e-15);
    }

    #[test]
    fn unused_cores_contribute_idle() {
        let cfg = SystemConfig::high_power(); // 8 cores
        let rs = stats_one_core(1000, 0, 0, 435 * 1000);
        let e = compute(&cfg, &rs);
        // 7 unused cores idle for 1000 cycles each.
        let expect = 7.0 * 1000.0 * 126.03e-12;
        assert!((e.core_idle_j - expect).abs() / expect < 0.01, "{e:?}");
    }

    #[test]
    fn static_power_scales_with_time() {
        let cfg = SystemConfig::low_power();
        let short = compute(&cfg, &stats_one_core(0, 0, 0, 1_000_000));
        let long = compute(&cfg, &stats_one_core(0, 0, 0, 2_000_000));
        assert!((long.mem_ctrl_io_j - 2.0 * short.mem_ctrl_io_j).abs() < 1e-18);
        assert!((long.llc_leakage_j - 2.0 * short.llc_leakage_j).abs() < 1e-18);
    }

    #[test]
    fn dram_energy_per_access() {
        let cfg = SystemConfig::high_power();
        let mut rs = stats_one_core(0, 0, 0, 1000);
        rs.dram_accesses = 1000;
        let e = compute(&cfg, &rs);
        assert!((e.dram_j - 1000.0 * 120e-12).abs() < 1e-15);
    }

    #[test]
    fn total_is_sum_of_parts() {
        let cfg = SystemConfig::high_power();
        let mut rs = stats_one_core(5000, 100, 10, 435 * 6000);
        rs.dram_accesses = 42;
        rs.llc_bytes_read = 4096;
        rs.aimc.energy_j = 1e-9;
        let e = compute(&cfg, &rs);
        let sum = e.core_total_j() + e.llc_dynamic_j + e.llc_leakage_j + e.dram_j
            + e.mem_ctrl_io_j + e.aimc_j;
        assert!((e.total_j() - sum).abs() < 1e-18);
        assert!(e.total_j() > 0.0);
    }
}
