//! Small self-contained utilities: deterministic RNG, property-test
//! helper, bench harness, table rendering, scoped-thread worker pool.
//! These substitute for crates (rand / proptest / criterion / rayon)
//! that the offline vendor set lacks — see DESIGN.md §2.

pub mod benchkit;
pub mod miniprop;
pub mod parallel;
pub mod rng;
pub mod table;
