//! Small self-contained utilities: deterministic RNG, property-test
//! helper, bench harness, table rendering. These substitute for crates
//! (rand / proptest / criterion) that the offline vendor set lacks —
//! see DESIGN.md §2.

pub mod benchkit;
pub mod miniprop;
pub mod rng;
pub mod table;
