//! Deterministic PRNG (xoshiro256++) — the offline build has no `rand`
//! crate, and the simulator must be bit-reproducible anyway.

/// xoshiro256++ by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, bound) (Lemire-style reduction, bias ~2^-64).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo) as u64 + 1) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill with N(0, sigma) f32 noise.
    pub fn normal_f32(&mut self, sigma: f32) -> f32 {
        (self.normal() as f32) * sigma
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(11);
        for bound in [1u64, 2, 3, 17, 1000] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn normal_moments_reasonable() {
        let mut r = Rng::new(42);
        let n = 20_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
