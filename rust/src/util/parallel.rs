//! Std-only scoped-thread worker pool for the sweep engine.
//!
//! The paper's evaluation is dozens of independent (workload x system)
//! simulations — fig10 alone is 42 full-system runs — and every
//! `sim::Machine` is self-contained, so the sweeps are embarrassingly
//! parallel. The offline vendor set has no rayon; this module provides
//! the one primitive the coordinator needs: an order-preserving
//! `parallel_map` built on `std::thread::scope`.
//!
//! Determinism contract: workers claim items through an atomic cursor
//! but every result is written back to the slot of its input index, so
//! the output order (and, because each job is independent and itself
//! deterministic, every output value) is identical to the serial path
//! regardless of worker count or scheduling.
//!
//! Worker count resolution (first match wins):
//!   1. `set_jobs(n)` — the CLI `--jobs N` flag;
//!   2. the `ALPINE_JOBS` environment variable;
//!   3. `std::thread::available_parallelism()`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Process-wide override installed by `--jobs` (0 = unset).
static JOBS_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Worker count resolved from ALPINE_JOBS / available parallelism on
/// first use (0 = not yet resolved), so the env var is parsed — and an
/// invalid value warned about — exactly once per process.
static JOBS_RESOLVED: AtomicUsize = AtomicUsize::new(0);

/// Install a process-wide worker-count override (the `--jobs` CLI knob).
pub fn set_jobs(n: usize) {
    JOBS_OVERRIDE.store(n.max(1), Ordering::Relaxed);
}

/// Resolve the worker count: `set_jobs` override, then `ALPINE_JOBS`,
/// then the machine's available parallelism.
pub fn jobs() -> usize {
    let n = JOBS_OVERRIDE.load(Ordering::Relaxed);
    if n > 0 {
        return n;
    }
    let cached = JOBS_RESOLVED.load(Ordering::Relaxed);
    if cached > 0 {
        return cached;
    }
    let resolved = match std::env::var("ALPINE_JOBS") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n,
            // Match the CLI flag's contract instead of silently fanning
            // out across all cores on a typo'd or zero value.
            _ => {
                eprintln!(
                    "alpine: warning: ignoring invalid ALPINE_JOBS={v:?} (expects a number >= 1)"
                );
                default_parallelism()
            }
        },
        Err(_) => default_parallelism(),
    };
    JOBS_RESOLVED.store(resolved, Ordering::Relaxed);
    resolved
}

fn default_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `items` on up to `jobs` scoped worker threads, returning
/// results in input order. `jobs <= 1` (or a single item) runs the exact
/// serial path inline with no threads spawned. A panicking job (e.g. a
/// simulated-deadlock panic) propagates to the caller once all workers
/// have drained, matching serial behaviour.
pub fn parallel_map<T, R, F>(items: Vec<T>, jobs: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if jobs <= 1 || n <= 1 {
        return items.into_iter().map(f).collect();
    }

    // Slot-per-item in/out tables: the Mutex is uncontended (each slot is
    // touched by exactly one worker) and exists only to hand `T: Send`
    // values across the thread boundary safely.
    let work: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let out: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    let workers = jobs.min(n);

    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = work[i]
                    .lock()
                    .expect("work slot poisoned")
                    .take()
                    .expect("work item claimed twice");
                let result = f(item);
                *out[i].lock().expect("result slot poisoned") = Some(result);
            });
        }
    });

    out.into_iter()
        .map(|slot| {
            slot.into_inner()
                .expect("result slot poisoned")
                .expect("worker skipped an item")
        })
        .collect()
}

/// Map `f` over `items` in parallel (order-preserving, like
/// [`parallel_map`]) and fold the results **in input order** into
/// `init` with `merge`. Because the fold order is the input order, the
/// reduction is bit-identical to the serial path for any merge
/// function, associative or not — the primitive the automap
/// branch-and-bound fan-out merges partition-subtree results with.
pub fn parallel_reduce<T, R, A, F, M>(items: Vec<T>, jobs: usize, init: A, f: F, mut merge: M) -> A
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
    M: FnMut(A, R) -> A,
{
    parallel_map(items, jobs, f).into_iter().fold(init, &mut merge)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<u64> = (0..257).collect();
        let expect: Vec<u64> = items.iter().map(|v| v * v).collect();
        for jobs in [1, 2, 3, 8, 64] {
            let got = parallel_map(items.clone(), jobs, |v| v * v);
            assert_eq!(got, expect, "jobs={jobs}");
        }
    }

    #[test]
    fn handles_more_workers_than_items() {
        let got = parallel_map(vec![10u32, 20], 16, |v| v + 1);
        assert_eq!(got, vec![11, 21]);
    }

    #[test]
    fn empty_and_single_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_map(empty, 8, |v| v).is_empty());
        assert_eq!(parallel_map(vec![5u32], 8, |v| v * 2), vec![10]);
    }

    #[test]
    fn serial_and_parallel_results_identical() {
        // Non-trivial per-item computation with item-dependent output.
        let items: Vec<u64> = (0..64).collect();
        let work = |seed: u64| -> u64 {
            let mut x = seed.wrapping_mul(0x9E3779B97F4A7C15) ^ 0xDEADBEEF;
            for _ in 0..1000 {
                x = x.rotate_left(7).wrapping_mul(31).wrapping_add(seed);
            }
            x
        };
        let serial = parallel_map(items.clone(), 1, work);
        let parallel = parallel_map(items, 6, work);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn panic_in_worker_propagates() {
        let result = std::panic::catch_unwind(|| {
            parallel_map(vec![1u32, 2, 3, 4], 2, |v| {
                if v == 3 {
                    panic!("simulated deadlock");
                }
                v
            })
        });
        assert!(result.is_err());
    }

    #[test]
    fn jobs_is_at_least_one() {
        assert!(jobs() >= 1);
    }

    #[test]
    fn reduce_folds_in_input_order() {
        // A non-associative, non-commutative merge: order mistakes show.
        let items: Vec<u64> = (1..=32).collect();
        let expect = items.iter().map(|v| v * 3).fold(String::new(), |acc, v| format!("{acc}/{v}"));
        for jobs in [1, 4, 16] {
            let got = parallel_reduce(items.clone(), jobs, String::new(), |v| v * 3, |acc, v| {
                format!("{acc}/{v}")
            });
            assert_eq!(got, expect, "jobs={jobs}");
        }
    }
}
