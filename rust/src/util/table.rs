//! ASCII table renderer for experiment reports (the figures in the paper
//! are bar charts; we print their underlying series as aligned tables and
//! CSV so they can be re-plotted).

#[derive(Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_time(seconds: f64) -> String {
    if seconds >= 1.0 {
        format!("{:.3}s", seconds)
    } else if seconds >= 1e-3 {
        format!("{:.3}ms", seconds * 1e3)
    } else if seconds >= 1e-6 {
        format!("{:.3}us", seconds * 1e6)
    } else {
        format!("{:.1}ns", seconds * 1e9)
    }
}

/// Format joules with an adaptive unit.
pub fn fmt_energy(joules: f64) -> String {
    if joules >= 1.0 {
        format!("{:.3}J", joules)
    } else if joules >= 1e-3 {
        format!("{:.3}mJ", joules * 1e3)
    } else if joules >= 1e-6 {
        format!("{:.3}uJ", joules * 1e6)
    } else {
        format!("{:.1}nJ", joules * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["case", "time", "energy"]);
        t.row(vec!["DIG-1".into(), "1.0ms".into(), "3uJ".into()]);
        t.row(vec!["ANA-1".into(), "80us".into(), "0.2uJ".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("DIG-1"));
        let lines: Vec<&str> = s.lines().collect();
        // header + rule + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    fn time_units() {
        assert_eq!(fmt_time(2.0), "2.000s");
        assert!(fmt_time(0.002).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("us"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }
}
