//! Minimal property-based testing helper (proptest is unavailable in the
//! offline vendor set). Provides seeded random-case generation with
//! shrink-free but *reproducible* failure reporting: a failing case prints
//! its case index and seed so it can be replayed exactly.

use super::rng::Rng;

/// Number of cases per property, overridable via ALPINE_PROP_CASES.
pub fn default_cases() -> usize {
    std::env::var("ALPINE_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases` random cases derived from `seed`. The closure
/// receives a fresh RNG per case; panics are annotated with the case index.
pub fn check<F: Fn(&mut Rng)>(name: &str, seed: u64, prop: F) {
    let cases = default_cases();
    for case in 0..cases {
        let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(
                "miniprop: property '{name}' failed at case {case}/{cases} (seed {seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u64-roundtrip", 1, |rng| {
            let v = rng.next_u64();
            assert_eq!(v, v);
        });
    }

    #[test]
    #[should_panic]
    fn reports_failing_property() {
        check("always-false", 2, |_rng| {
            assert!(false);
        });
    }

    #[test]
    fn case_seeds_are_distinct() {
        // Two different case indices must see different RNG streams.
        let mut seen = std::collections::HashSet::new();
        for case in 0..32u64 {
            let mut rng = Rng::new(99 ^ case.wrapping_mul(0x9E3779B97F4A7C15));
            assert!(seen.insert(rng.next_u64()));
        }
    }
}
