//! Tiny benchmarking harness for `cargo bench` targets (criterion is not in
//! the offline vendor set). Measures wall-clock over repeated runs and
//! reports mean / stddev / min, plus helpers for printing the paper's
//! table rows.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub iters: u32,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<44} mean {:>12}  sd {:>10}  min {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.stddev_ns),
            fmt_ns(self.min_ns),
            self.iters
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} us", ns / 1e3)
    } else {
        format!("{:.0} ns", ns)
    }
}

/// Time `f` `iters` times (after one warmup) and report statistics.
pub fn bench<F: FnMut()>(name: &str, iters: u32, mut f: F) -> BenchResult {
    f(); // warmup
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        mean_ns: mean,
        stddev_ns: var.sqrt(),
        min_ns: min,
        iters,
    };
    r.report();
    r
}

/// `black_box` stand-in (std::hint::black_box is stable since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = bench("noop", 5, || {
            black_box(1 + 1);
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_ns >= 0.0);
        assert!(r.min_ns <= r.mean_ns + 1.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("us"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2.5e9).ends_with(" s"));
    }
}
