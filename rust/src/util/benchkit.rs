//! Tiny benchmarking harness for `cargo bench` targets (criterion is not in
//! the offline vendor set). Measures wall-clock over repeated runs and
//! reports mean / stddev / min, plus helpers for printing the paper's
//! table rows.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub iters: u32,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<44} mean {:>12}  sd {:>10}  min {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.stddev_ns),
            fmt_ns(self.min_ns),
            self.iters
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} us", ns / 1e3)
    } else {
        format!("{:.0} ns", ns)
    }
}

/// Time `f` `iters` times (after one warmup) and report statistics.
pub fn bench<F: FnMut()>(name: &str, iters: u32, mut f: F) -> BenchResult {
    f(); // warmup
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        mean_ns: mean,
        stddev_ns: var.sqrt(),
        min_ns: min,
        iters,
    };
    r.report();
    r
}

/// `black_box` stand-in (std::hint::black_box is stable since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Write a machine-readable bench summary (name -> mean/min/stddev ns)
/// so the perf trajectory is trackable across PRs. Hand-rolled JSON —
/// serde is not in the offline vendor set. Bench names are ASCII
/// identifiers chosen by us, so no string escaping is needed.
pub fn json_report(results: &[BenchResult], path: &str) -> std::io::Result<()> {
    let mut s = String::from("{\n");
    for (i, r) in results.iter().enumerate() {
        s.push_str(&format!(
            "  \"{}\": {{\"mean_ns\": {:.1}, \"min_ns\": {:.1}, \"stddev_ns\": {:.1}, \"iters\": {}}}{}\n",
            r.name,
            r.mean_ns,
            r.min_ns,
            r.stddev_ns,
            r.iters,
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    s.push_str("}\n");
    std::fs::write(path, s)?;
    println!("benchkit: wrote {} result(s) to {path}", results.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = bench("noop", 5, || {
            black_box(1 + 1);
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_ns >= 0.0);
        assert!(r.min_ns <= r.mean_ns + 1.0);
    }

    #[test]
    fn json_report_writes_parseable_object() {
        let results = vec![
            BenchResult { name: "a/one".into(), mean_ns: 1234.5, stddev_ns: 10.0, min_ns: 1200.0, iters: 5 },
            BenchResult { name: "b/two".into(), mean_ns: 8.0, stddev_ns: 0.5, min_ns: 7.5, iters: 9 },
        ];
        let dir = std::env::temp_dir().join("alpine_benchkit_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        json_report(&results, path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.trim_start().starts_with('{'));
        assert!(text.trim_end().ends_with('}'));
        assert!(text.contains("\"a/one\""));
        assert!(text.contains("\"mean_ns\": 1234.5"));
        assert!(text.contains("\"b/two\""));
        // Exactly one comma separator between the two entries.
        assert_eq!(text.matches("},").count(), 1);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("us"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2.5e9).ends_with(" s"));
    }
}
