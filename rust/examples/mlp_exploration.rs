//! Exploration One (§VII): the full MLP study — all digital core counts,
//! all four analog mappings, the loose-coupling comparison, and the
//! sub-ROI breakdown, on both systems.
//!
//!     cargo run --release --example mlp_exploration

use alpine::coordinator::experiments;
use alpine::report;

fn main() {
    let n = experiments::MLP_INFERENCES;

    let rows = experiments::fig7_mlp(n).unwrap();
    report::aggregate_table("MLP aggregate (Fig. 7)", &rows).print();
    report::gains_table("Gains vs DIG-1core (paper max: 12.8x time / 12.5x energy)", &rows, |r| {
        r.label.contains("DIG-1core")
    })
    .print();

    let breakdown = experiments::fig8_mlp_breakdown(n).unwrap();
    report::roi_table("Sub-ROI breakdown (Fig. 8)", &breakdown).print();

    let coupling = experiments::loose_vs_tight(n).unwrap();
    report::aggregate_table("Loose vs tight coupling (§VII.B)", &coupling).print();

    // The paper's multi-core observation: Case 1 outperforms Cases 3/4.
    let hp: Vec<_> = rows
        .iter()
        .filter(|r| r.system == alpine::config::SystemKind::HighPower)
        .collect();
    let c1 = hp.iter().find(|r| r.label.contains("case1")).unwrap();
    let c3 = hp.iter().find(|r| r.label.contains("case3")).unwrap();
    let c4 = hp.iter().find(|r| r.label.contains("case4")).unwrap();
    println!(
        "\nmulti-core check (§VII.C): case1 is {:.0}% faster than case3, {:.0}% faster than case4",
        100.0 * (c3.time_s / c1.time_s - 1.0),
        100.0 * (c4.time_s / c1.time_s - 1.0),
    );
}
