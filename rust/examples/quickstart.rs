//! Quickstart: map a small network onto a tightly-coupled AIMC system,
//! run it functionally through AIMClib, then simulate its timing and
//! energy on both Table-I systems.
//!
//!     cargo run --release --example quickstart

use alpine::aimclib::checker::{self, Matrix};
use alpine::aimclib::{activation, AimcDevice};
use alpine::config::{SystemConfig, SystemKind};
use alpine::coordinator::{run_workload, RunOptions};
use alpine::util::rng::Rng;
use alpine::util::table::fmt_time;
use alpine::workload::mlp::{self, MlpCase};

fn main() -> anyhow::Result<()> {
    println!("== ALPINE quickstart ==\n");

    // ------------------------------------------------------------------
    // 1. Functional path: program a 256x128 matrix onto an AIMC device
    //    and run one inference through AIMClib (Fig. 4 of the paper).
    // ------------------------------------------------------------------
    let mut rng = Rng::new(42);
    let m = 256;
    let n = 128;
    let x = Matrix::new(1, m, (0..m).map(|_| rng.normal_f32(1.0)).collect());
    let w = Matrix::new(m, n, (0..m * n).map(|_| rng.normal_f32(0.1)).collect());

    let (w_q, _w_scale) = checker::quantize_weights(&w);
    let w_prog = checker::program_weights(&w_q, 0.01, &mut rng);
    let spec = checker::calibrate(&x, &w, m, n);

    let mut dev = AimcDevice::new(m, n, spec);
    dev.map_matrix(0, 0, &w_prog)?; // CM_INITIALIZE
    dev.queue_vector(0, &x.data)?; // CM_QUEUE
    dev.process(); // CM_PROCESS (analog MVM, 100 ns on hardware)
    let mut y = vec![0.0f32; n];
    dev.dequeue_vector(0, &mut y)?; // CM_DEQUEUE
    activation::relu(&mut y);

    // Compare against the exact product.
    let mut exact = vec![0.0f32; n];
    for j in 0..n {
        let mut acc = 0.0;
        for i in 0..m {
            acc += x.at(0, i) * w.at(i, j);
        }
        exact[j] = acc.max(0.0);
    }
    let err: f32 = y
        .iter()
        .zip(&exact)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f32>()
        .sqrt()
        / exact.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-9);
    println!("functional AIMC inference: relative error vs exact fp32 = {err:.3}");
    assert!(err < 0.1, "analog inference should track the exact result");

    // ------------------------------------------------------------------
    // 2. Timing path: simulate the paper's MLP on both systems,
    //    digital reference vs analog case 1.
    // ------------------------------------------------------------------
    println!("\nfull-system simulation (10 inferences of the 1024x1024x2 MLP):\n");
    for kind in SystemKind::ALL {
        let cfg = SystemConfig::for_kind(kind);
        let ro = RunOptions::default();
        let dig = run_workload(kind, mlp::generate(MlpCase::Digital { cores: 1 }, &cfg, 10).unwrap(), &ro).unwrap();
        let ana = run_workload(kind, mlp::generate(MlpCase::Analog { case: 1 }, &cfg, 10).unwrap(), &ro).unwrap();
        println!(
            "  [{:>10}] DIG {:>10}/inf  ANA {:>10}/inf  => speedup {:>5.1}x, energy gain {:>5.1}x",
            kind.name(),
            fmt_time(dig.time_per_inference_s),
            fmt_time(ana.time_per_inference_s),
            dig.time_s / ana.time_s,
            dig.energy.total_j() / ana.energy.total_j(),
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
