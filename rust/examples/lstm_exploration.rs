//! Exploration Two (§VIII): the LSTM study across n_h in {256,512,750},
//! digital vs analog cases, plus the working-set analysis that explains
//! the scaling of the gains.
//!
//!     cargo run --release --example lstm_exploration

use alpine::config::SystemKind;
use alpine::coordinator::experiments;
use alpine::nn::LstmModel;
use alpine::report;

fn main() {
    let rows = experiments::fig10_lstm(experiments::LSTM_INFERENCES).unwrap();
    report::aggregate_table("LSTM aggregate (Fig. 10)", &rows).print();

    for n_h in experiments::LSTM_SIZES {
        let m = LstmModel::paper(n_h);
        println!(
            "n_h={n_h}: digital working set {:.2} kB, analog {:.2} kB (§VIII.E)",
            m.working_set_digital() as f64 / 1024.0,
            m.working_set_analog() as f64 / 1024.0
        );
        let sized: Vec<_> = rows
            .iter()
            .filter(|r| {
                r.system == SystemKind::HighPower && r.label.starts_with(&format!("lstm{n_h}/"))
            })
            .cloned()
            .collect();
        report::gains_table(
            &format!("Gains vs DIG-1core, n_h={n_h} (paper: up to 9.4x/9.3x at 750)"),
            &sized,
            |r| r.label.ends_with("DIG-1core"),
        )
        .print();
    }

    let breakdown = experiments::fig11_lstm_breakdown(experiments::LSTM_INFERENCES).unwrap();
    report::roi_table("LSTM analog sub-ROI breakdown (Fig. 11)", &breakdown).print();
}
