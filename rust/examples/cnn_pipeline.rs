//! Exploration Three (§IX): the 8-core pipelined CNN study — CNN-F/M/S,
//! digital vs analog convolutions, with the per-core utilization view
//! of Fig. 14.
//!
//!     cargo run --release --example cnn_pipeline

use alpine::coordinator::experiments;
use alpine::nn::{CnnModel, CnnVariant};
use alpine::report;

fn main() {
    // Architecture summary (Fig. 12b).
    for v in CnnVariant::ALL {
        let m = CnnModel::paper(v);
        println!(
            "{}: {} conv layers, {:.2}M AIMC params (paper {:.1}M), {:.1}M dense params, {:.0}M conv MACs/inference",
            v.name(),
            m.convs.len(),
            m.aimc_params() as f64 / 1e6,
            v.paper_aimc_params() / 1e6,
            m.dense_params() as f64 / 1e6,
            m.conv_macs() as f64 / 1e6,
        );
    }
    println!();

    let rows = experiments::fig13_cnn(experiments::CNN_INFERENCES).unwrap();
    report::aggregate_table("CNN aggregate (Fig. 13)", &rows).print();
    report::gains_table(
        "Gains vs DIG (paper: up to 20.5x/20.8x on CNN-S high-power)",
        &rows,
        |r| r.label.contains("CNN-S") && r.label.ends_with("DIG"),
    )
    .print();

    let util = experiments::fig14_cnn_utilization(experiments::CNN_INFERENCES).unwrap();
    report::utilization_table(
        "CNN-S per-core utilization (Fig. 14; cores 0-4 conv, 5-7 dense)",
        &util,
    )
    .print();
}
