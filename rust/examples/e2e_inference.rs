//! End-to-end driver: proves all layers compose on a real workload.
//!
//! 1. Loads the AOT-compiled JAX/Pallas artifacts (Layer 2/1, built once
//!    by `make artifacts`) into the Rust PJRT runtime — Python is not on
//!    this path.
//! 2. Validates every model's numerics against its AOT-time probe.
//! 3. Serves 256 batched MLP inference requests through the batching
//!    dispatcher, reporting latency/throughput, and cross-checks the
//!    analog model's outputs against the digital reference (the paper's
//!    iso-accuracy argument) and against AIMClib's host checker.
//! 4. Runs an LSTM character-generation loop (PTB-style synthetic
//!    alphabet) with recurrent state threading through PJRT.
//! 5. Reports what the *simulated* ALPINE hardware would achieve on the
//!    same workload (time/energy per inference, speedup vs digital).
//!
//!     make artifacts && cargo run --release --example e2e_inference

use alpine::config::SystemKind;
use alpine::coordinator::{run_workload, server, RunOptions};
use alpine::runtime::{default_artifacts_dir, read_f32_bin, Runtime};
use alpine::util::rng::Rng;
use alpine::util::table::fmt_time;
use alpine::workload::mlp::{self, MlpCase};
use anyhow::{ensure, Context, Result};

fn main() -> Result<()> {
    let dir = default_artifacts_dir();
    let rt = Runtime::new(&dir)
        .context("PJRT init failed — run `make artifacts` first")?;
    println!("PJRT platform: {}", rt.platform());

    // ------------------------------------------------------------------
    // 1+2. Load every artifact and probe-check its numerics.
    // ------------------------------------------------------------------
    let models = rt.available_models()?;
    println!("artifacts: {models:?}");
    for name in &models {
        let m = rt.load(name)?;
        let (max_abs, rel) = m.probe_check()?;
        ensure!(rel < 1e-5, "{name}: probe rel err {rel}");
        println!("  probe {name:<18} max_abs={max_abs:.2e} rel={rel:.2e}  OK");
    }

    // ------------------------------------------------------------------
    // 3. Batched serving through the analog MLP (batch dimension 8).
    // ------------------------------------------------------------------
    let analog = rt.load("mlp_analog_b8")?;
    let digital = rt.load("mlp_digital_b8")?;
    let dim = 1024usize;
    let mut rng = Rng::new(7);
    let requests: Vec<Vec<f32>> = (0..256)
        .map(|_| (0..dim).map(|_| rng.normal_f32(1.0)).collect())
        .collect();

    // NOTE: the b8 artifact takes a whole batch as one input; the server
    // packs up to 8 requests per execution. Arrivals follow a seeded
    // uniform process (20 kHz == the legacy 50 us jitter), so the run is
    // a reproducible schedule.
    let arrival = server::ArrivalSpec::uniform(20_000.0, 7);
    let t0 = std::time::Instant::now();
    let (responses, stats) = server::serve_batched(&analog, requests.clone(), 8, dim, &arrival)?;
    let pcts = stats.percentiles(&[50.0, 95.0, 99.0]);
    println!(
        "\nserved {} requests in {:?}: mean latency {:?} (p50 {:?} / p95 {:?} / p99 {:?}, max {:?}), {:.0} req/s, mean batch {:.1}",
        stats.requests,
        t0.elapsed(),
        stats.mean_latency(),
        pcts[0],
        pcts[1],
        pcts[2],
        stats.max_latency,
        stats.throughput_rps(),
        stats.mean_batch()
    );

    // Analog vs digital agreement on the same requests.
    let (dig_responses, _) = server::serve_batched(&digital, requests, 8, dim, &arrival)?;
    let mut rel_acc = 0.0f64;
    let n_cmp = responses.len().min(dig_responses.len());
    for (a, d) in responses.iter().zip(dig_responses.iter()).take(n_cmp) {
        let num: f64 = a
            .output
            .iter()
            .zip(&d.output)
            .map(|(x, y)| ((x - y) * (x - y)) as f64)
            .sum();
        let den: f64 = d.output.iter().map(|y| (y * y) as f64).sum();
        rel_acc += (num / den.max(1e-30)).sqrt();
    }
    let mean_rel = rel_acc / n_cmp as f64;
    println!("analog-vs-digital mean relative error over {n_cmp} requests: {mean_rel:.3}");
    ensure!(mean_rel < 0.25, "analog should track digital (PCM noise + ADC quantization only)");

    // ------------------------------------------------------------------
    // 4. LSTM: recurrent character loop on a synthetic PTB-like alphabet.
    // ------------------------------------------------------------------
    let lstm = rt.load("lstm256_analog")?;
    let mut h = vec![0.0f32; 256];
    let mut c = vec![0.0f32; 256];
    // Seed character: one-hot-ish probe from the bundle.
    let mut x = read_f32_bin(&lstm.manifest.inputs[0].file)?;
    let mut generated = Vec::new();
    for _step in 0..20 {
        let out = lstm.run(&[x.clone(), h.clone(), c.clone()])?;
        let (y, h2, c2) = (&out[0], &out[1], &out[2]);
        // Greedy next char.
        let next = y
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        generated.push(next);
        h = h2.clone();
        c = c2.clone();
        x = vec![0.0; 50];
        x[next] = 1.0;
    }
    println!("LSTM generated symbol stream: {generated:?}");
    ensure!(generated.len() == 20);

    // ------------------------------------------------------------------
    // 5. What the simulated ALPINE hardware does with this workload.
    // ------------------------------------------------------------------
    println!("\nsimulated ALPINE hardware on the same MLP workload (10 inferences):");
    for kind in SystemKind::ALL {
        let cfg = alpine::config::SystemConfig::for_kind(kind);
        let ro = RunOptions::default();
        let dig = run_workload(kind, mlp::generate(MlpCase::Digital { cores: 1 }, &cfg, 10).unwrap(), &ro).unwrap();
        let ana = run_workload(kind, mlp::generate(MlpCase::Analog { case: 1 }, &cfg, 10).unwrap(), &ro).unwrap();
        println!(
            "  [{:>10}] ANA {:>9}/inf {:>10.3e} J/inf | speedup {:>5.1}x energy {:>5.1}x vs DIG",
            kind.name(),
            fmt_time(ana.time_per_inference_s),
            ana.energy_per_inference_j(),
            dig.time_s / ana.time_s,
            dig.energy.total_j() / ana.energy.total_j(),
        );
    }
    println!("\ne2e_inference OK");
    Ok(())
}
