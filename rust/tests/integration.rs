//! Integration tests across the simulator stack: workload generators →
//! trace machine → stats → energy, checking the paper's qualitative
//! claims end to end (the quantitative paper-vs-measured table lives in
//! EXPERIMENTS.md and the benches).

use alpine::config::{SystemConfig, SystemKind};
use alpine::coordinator::{energy_gain, speedup, CaseResult, RunOptions};
use alpine::nn::{CnnVariant, LstmModel, MlpModel};
use alpine::sim::RunError;
use alpine::stats::RoiKind;
use alpine::workload::cnn::{self, CnnCase};
use alpine::workload::lstm::{self, LstmCase};
use alpine::workload::mlp::{self, MlpCase};
use alpine::workload::Workload;

fn hp() -> SystemConfig {
    SystemConfig::high_power()
}

/// Every run in this file uses the default knobs; keep the dozens of
/// call sites terse.
fn run_workload(kind: SystemKind, w: Workload) -> Result<CaseResult, RunError> {
    alpine::coordinator::run_workload(kind, w, &RunOptions::default())
}

// ---------------------------------------------------------------------------
// MLP (§VII)
// ---------------------------------------------------------------------------

#[test]
fn mlp_analog_beats_digital_on_both_systems() {
    for kind in SystemKind::ALL {
        let cfg = SystemConfig::for_kind(kind);
        let dig = run_workload(kind, mlp::generate(MlpCase::Digital { cores: 1 }, &cfg, 5).unwrap()).unwrap();
        let ana = run_workload(kind, mlp::generate(MlpCase::Analog { case: 1 }, &cfg, 5).unwrap()).unwrap();
        let s = speedup(&dig, &ana);
        let e = energy_gain(&dig, &ana);
        assert!(s > 4.0, "[{}] speedup {s}", kind.name());
        assert!(e > 4.0, "[{}] energy gain {e}", kind.name());
    }
}

#[test]
fn mlp_case1_slightly_beats_case2() {
    // §VII.B: case 1 wins "by a slight margin" (2x the CM_PROCESS calls
    // in case 2, but process is a small slice of the ROI).
    let c1 = run_workload(SystemKind::HighPower, mlp::generate(MlpCase::Analog { case: 1 }, &hp(), 10).unwrap()).unwrap();
    let c2 = run_workload(SystemKind::HighPower, mlp::generate(MlpCase::Analog { case: 2 }, &hp(), 10).unwrap()).unwrap();
    assert!(c1.time_s < c2.time_s, "case1 {} vs case2 {}", c1.time_s, c2.time_s);
    assert!(c2.time_s / c1.time_s < 1.6, "margin should be slight: {}", c2.time_s / c1.time_s);
}

#[test]
fn mlp_multicore_analog_is_slower_than_single_core() {
    // §VII.C: "the performance and energy of the system worsens with
    // increasing number of CPU cores" for the analog MLP.
    let c1 = run_workload(SystemKind::HighPower, mlp::generate(MlpCase::Analog { case: 1 }, &hp(), 10).unwrap()).unwrap();
    let c3 = run_workload(SystemKind::HighPower, mlp::generate(MlpCase::Analog { case: 3 }, &hp(), 10).unwrap()).unwrap();
    let c4 = run_workload(SystemKind::HighPower, mlp::generate(MlpCase::Analog { case: 4 }, &hp(), 10).unwrap()).unwrap();
    assert!(c1.time_s < c3.time_s, "case1 should beat case3");
    assert!(c1.time_s < c4.time_s, "case1 should beat case4");
    assert!(c3.time_s < c4.time_s, "case3 should beat case4");
}

#[test]
fn mlp_analog_memory_intensity_much_lower() {
    // Fig. 7 middle column: LLCMPI drops sharply for analog mappings
    // (weights never traverse the hierarchy).
    let dig = run_workload(SystemKind::HighPower, mlp::generate(MlpCase::Digital { cores: 1 }, &hp(), 5).unwrap()).unwrap();
    let ana = run_workload(SystemKind::HighPower, mlp::generate(MlpCase::Analog { case: 1 }, &hp(), 5).unwrap()).unwrap();
    assert!(
        dig.llc_mpki > 5.0 * ana.llc_mpki.max(1e-6),
        "dig {} vs ana {}",
        dig.llc_mpki,
        ana.llc_mpki
    );
}

#[test]
fn mlp_digital_dominated_by_mvm_analog_by_linear_ops() {
    // Fig. 8: the reference spends most time in the digital MVM; the
    // analog cases in input load + queue/dequeue (linear terms).
    let dig = run_workload(SystemKind::HighPower, mlp::generate(MlpCase::Digital { cores: 1 }, &hp(), 5).unwrap()).unwrap();
    assert!(dig.roi.fraction(RoiKind::DigitalMvm) > 0.6, "{:?}", dig.roi.breakdown());

    let ana = run_workload(SystemKind::HighPower, mlp::generate(MlpCase::Analog { case: 1 }, &hp(), 5).unwrap()).unwrap();
    let linear = ana.roi.fraction(RoiKind::InputLoad)
        + ana.roi.fraction(RoiKind::AnalogQueue)
        + ana.roi.fraction(RoiKind::AnalogDequeue);
    assert!(linear > 0.5, "linear ops should dominate: {:?}", ana.roi.breakdown());
    assert!(
        ana.roi.fraction(RoiKind::AnalogProcess) < 0.15,
        "process should be minor: {:?}",
        ana.roi.breakdown()
    );
}

#[test]
fn mlp_loose_between_digital_and_tight() {
    // §VII.B: loose ~4.1x over digital, ~3.1x slower than tight.
    let dig = run_workload(SystemKind::HighPower, mlp::generate(MlpCase::Digital { cores: 1 }, &hp(), 5).unwrap()).unwrap();
    let tight = run_workload(SystemKind::HighPower, mlp::generate(MlpCase::Analog { case: 1 }, &hp(), 5).unwrap()).unwrap();
    let loose = run_workload(SystemKind::HighPower, mlp::generate(MlpCase::AnalogLoose, &hp(), 5).unwrap()).unwrap();
    let s_loose = dig.time_s / loose.time_s;
    let slowdown = loose.time_s / tight.time_s;
    assert!(s_loose > 1.5, "loose over digital: {s_loose}");
    assert!(slowdown > 1.5, "tight over loose: {slowdown}");
}

#[test]
fn mlp_working_set_drives_dram_traffic() {
    // The digital working set (2.1 MB) exceeds the HP LLC (1 MB): every
    // inference must re-stream weights from DRAM.
    let dig = run_workload(SystemKind::HighPower, mlp::generate(MlpCase::Digital { cores: 1 }, &hp(), 4).unwrap()).unwrap();
    let model = MlpModel::paper();
    let lines_per_inf = model.total_weight_bytes() / 64;
    assert!(
        dig.dram_accesses > 3 * lines_per_inf,
        "expected weight re-streaming: {} accesses",
        dig.dram_accesses
    );
}

// ---------------------------------------------------------------------------
// LSTM (§VIII)
// ---------------------------------------------------------------------------

#[test]
fn lstm_gains_grow_with_hidden_size() {
    // Fig. 10: n_h=256 ~1.0-1.5x; gains grow through 512 and 750.
    let mut prev = 0.0;
    for n_h in [256u64, 512, 750] {
        let dig = run_workload(
            SystemKind::HighPower,
            lstm::generate(LstmCase::Digital { cores: 1 }, n_h, &hp(), 5).unwrap(),
        ).unwrap();
        let ana = run_workload(
            SystemKind::HighPower,
            lstm::generate(LstmCase::Analog { case: 1 }, n_h, &hp(), 5).unwrap(),
        ).unwrap();
        let s = speedup(&dig, &ana);
        assert!(s > prev, "gain should grow with n_h: {s} at {n_h} (prev {prev})");
        prev = s;
    }
    assert!(prev > 3.0, "largest LSTM should see substantial gains: {prev}");
}

#[test]
fn lstm_multicore_analog_helps_unlike_mlp() {
    // §VIII.C: case 4 beats case 1 by ~10% (parallelized linear ops).
    let c1 = run_workload(
        SystemKind::HighPower,
        lstm::generate(LstmCase::Analog { case: 1 }, 750, &hp(), 10).unwrap(),
    ).unwrap();
    let c4 = run_workload(
        SystemKind::HighPower,
        lstm::generate(LstmCase::Analog { case: 4 }, 750, &hp(), 10).unwrap(),
    ).unwrap();
    assert!(c4.time_s < c1.time_s, "case4 {} should beat case1 {}", c4.time_s, c1.time_s);
}

#[test]
fn lstm_analog_bottleneck_is_dequeue_plus_activation() {
    // Fig. 11: cell dequeue + activations dominate the analog LSTM.
    let ana = run_workload(
        SystemKind::HighPower,
        lstm::generate(LstmCase::Analog { case: 1 }, 750, &hp(), 5).unwrap(),
    ).unwrap();
    let deq_act = ana.roi.fraction(RoiKind::AnalogDequeue) + ana.roi.fraction(RoiKind::Activation);
    assert!(deq_act > 0.4, "dequeue+activation should dominate: {:?}", ana.roi.breakdown());
}

#[test]
fn lstm_digital_dominated_by_cell_mvm() {
    // §VIII: 87.8-97.9% of digital ROI in the MVM+activation region.
    let dig = run_workload(
        SystemKind::HighPower,
        lstm::generate(LstmCase::Digital { cores: 1 }, 750, &hp(), 5).unwrap(),
    ).unwrap();
    let mvm_act = dig.roi.fraction(RoiKind::DigitalMvm)
        + dig.roi.fraction(RoiKind::Activation)
        + dig.roi.fraction(RoiKind::GateCombine);
    assert!(mvm_act > 0.8, "{:?}", dig.roi.breakdown());
}

#[test]
fn lstm_working_sets_match_section_8e() {
    // Digital within 16% of the paper (weight-only formula; the paper's
    // totals include per-gate biases, same delta as Table II); analog
    // formula is exact.
    for (n_h, dig_kb, ana_b) in [(256u64, 378.0, 662.0), (512, 1280.0, 1174.0), (750, 2590.0, 1650.0)] {
        let m = LstmModel::paper(n_h);
        let dig = m.working_set_digital() as f64 / 1000.0; // paper uses kB≈1000B here
        let ana = m.working_set_analog() as f64;
        assert!((dig - dig_kb).abs() / dig_kb < 0.16, "n_h={n_h} digital ws {dig}");
        assert!((ana - ana_b).abs() / ana_b < 0.12, "n_h={n_h} analog ws {ana}");
    }
}

// ---------------------------------------------------------------------------
// CNN (§IX)
// ---------------------------------------------------------------------------

#[test]
fn cnn_analog_beats_digital_all_variants() {
    for variant in CnnVariant::ALL {
        let dig = run_workload(
            SystemKind::HighPower,
            cnn::generate(CnnCase::Digital, variant, &hp(), 1).unwrap(),
        ).unwrap();
        let ana = run_workload(
            SystemKind::HighPower,
            cnn::generate(CnnCase::Analog, variant, &hp(), 1).unwrap(),
        ).unwrap();
        let s = speedup(&dig, &ana);
        assert!(s > 3.0, "{}: speedup {s}", variant.name());
    }
}

#[test]
fn cnn_s_sees_largest_gains() {
    // Fig. 13: the largest speedup is recorded for CNN-S.
    let mut gains = Vec::new();
    for variant in CnnVariant::ALL {
        let dig = run_workload(
            SystemKind::HighPower,
            cnn::generate(CnnCase::Digital, variant, &hp(), 1).unwrap(),
        ).unwrap();
        let ana = run_workload(
            SystemKind::HighPower,
            cnn::generate(CnnCase::Analog, variant, &hp(), 1).unwrap(),
        ).unwrap();
        gains.push((variant.name(), speedup(&dig, &ana)));
    }
    let s_gain = gains.iter().find(|(n, _)| *n == "CNN-S").unwrap().1;
    for (name, g) in &gains {
        assert!(s_gain >= *g * 0.95, "CNN-S ({s_gain:.1}x) should lead; {name} = {g:.1}x");
    }
}

#[test]
fn cnn_dense_cores_idle_most_in_digital() {
    // Fig. 14: the fully-connected layers' cores spend the most time
    // idling (they run once per inference vs the conv loops).
    let dig = run_workload(
        SystemKind::HighPower,
        cnn::generate(CnnCase::Digital, CnnVariant::Slow, &hp(), 2).unwrap(),
    ).unwrap();
    let conv_idle: f64 = dig.per_core_idle[..5].iter().sum::<f64>() / 5.0;
    let dense_idle: f64 = dig.per_core_idle[5..8].iter().sum::<f64>() / 3.0;
    assert!(
        dense_idle > conv_idle,
        "dense cores should idle more: conv {conv_idle:.2} dense {dense_idle:.2}"
    );
}

#[test]
fn cnn_memory_traffic_improves_with_aimc() {
    // Fig. 13 + §IX.B report a 3.7x *memory intensity* (LLC misses per
    // instruction) improvement. Our digital baseline is more
    // instruction-rich than gem5's, which deflates its MPKI, so we check
    // the underlying physical effect instead: the AIMC mapping moves far
    // less data through the memory system (conv weights never stream).
    let dig = run_workload(
        SystemKind::HighPower,
        cnn::generate(CnnCase::Digital, CnnVariant::Slow, &hp(), 1).unwrap(),
    ).unwrap();
    let ana = run_workload(
        SystemKind::HighPower,
        cnn::generate(CnnCase::Analog, CnnVariant::Slow, &hp(), 1).unwrap(),
    ).unwrap();
    assert!(
        dig.dram_accesses as f64 > 1.5 * ana.dram_accesses as f64,
        "dig {} vs ana {}",
        dig.dram_accesses,
        ana.dram_accesses
    );
}

// ---------------------------------------------------------------------------
// Cross-cutting
// ---------------------------------------------------------------------------

#[test]
fn low_power_system_sees_smaller_gains_than_high_power() {
    // §VII.C: "the low-power system exhibits lower performance gains in
    // comparison to the high-power system" (smaller L1).
    let gain = |kind: SystemKind| {
        let cfg = SystemConfig::for_kind(kind);
        let dig = run_workload(kind, mlp::generate(MlpCase::Digital { cores: 1 }, &cfg, 5).unwrap()).unwrap();
        let ana = run_workload(kind, mlp::generate(MlpCase::Analog { case: 1 }, &cfg, 5).unwrap()).unwrap();
        speedup(&dig, &ana)
    };
    let hp_gain = gain(SystemKind::HighPower);
    let lp_gain = gain(SystemKind::LowPower);
    assert!(
        hp_gain > lp_gain,
        "HP gain {hp_gain:.1} should exceed LP gain {lp_gain:.1}"
    );
}

#[test]
fn simulation_is_deterministic() {
    let run = || {
        run_workload(SystemKind::HighPower, mlp::generate(MlpCase::Analog { case: 3 }, &hp(), 3).unwrap()).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.time_s, b.time_s);
    assert_eq!(a.total_insts, b.total_insts);
    assert_eq!(a.dram_accesses, b.dram_accesses);
}

#[test]
fn process_latency_insensitivity() {
    // §VII.C: "even estimates of the latency increased 10x are observed
    // to have minimal impact" — check CM_PROCESS is a small ROI share.
    let ana = run_workload(SystemKind::HighPower, mlp::generate(MlpCase::Analog { case: 1 }, &hp(), 10).unwrap()).unwrap();
    assert!(ana.roi.fraction(RoiKind::AnalogProcess) < 0.2, "{:?}", ana.roi.breakdown());
}
