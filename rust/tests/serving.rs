//! The serving-robustness gate: admission control, timeout-drop, and
//! bounded retry must resolve every offered request to a *typed*
//! outcome (served / shed / timed-out — conservation), replica
//! hard-failure must end in failover-or-typed-shed and never a panic,
//! and a serve-bench report must be byte-identical in its seed at any
//! `--jobs N`. CI runs this file under the `fault-determinism` job and
//! the byte-identity test under the rust determinism gate.

use alpine::config::SystemKind;
use alpine::coordinator::serving::backend::InstantMockBackend;
use alpine::coordinator::serving::router::{self, SimConfig};
use alpine::coordinator::serving::{
    run_serve_bench_on, AccuracyModel, ArrivalProcess, Backend, RecalConfig, RecalPolicy,
    RouterPolicy, ServeBenchOptions, TraceMachineBackend,
};
use alpine::util::miniprop;

fn mock() -> InstantMockBackend {
    InstantMockBackend::default() // batch_ps(b) = 10_000 + 1_000 b, degraded x3
}

fn base_cfg(backend: &InstantMockBackend) -> SimConfig<'_> {
    SimConfig {
        backend,
        replicas: 1,
        queue_cap: 32,
        deadline_ps: 200_000,
        batch_wait_ps: 0,
        max_retries: 3,
        backoff_base_ps: 1_000,
        repair_ps: 1_000_000,
        policy: RouterPolicy::LeastLoaded,
        fail: None,
        recal: None,
    }
}

// ---------------------------------------------------------------------
// Typed resolution: admission control, timeout, retry budget
// ---------------------------------------------------------------------

#[test]
fn admission_backpressure_sheds_typed_queue_full() {
    let b = mock();
    let cfg = SimConfig { queue_cap: 4, ..base_cfg(&b) };
    // 64 simultaneous arrivals into one replica with a 4-deep queue:
    // whatever admission cannot hold is a typed queue_full shed, never
    // a silent drop.
    let res = router::simulate(&cfg, &vec![100; 64]);
    assert!(res.counters.shed_queue_full > 0);
    assert_eq!(res.counters.shed_no_replica, 0);
    assert_eq!(res.counters.shed_retries, 0);
    assert!(res.counters.conserved(), "{:?}", res.counters);
}

#[test]
fn expired_queue_entries_time_out_typed() {
    let b = mock();
    // One replica, 16 simultaneous arrivals, deadline 20 us. The first
    // launches alone (batch_wait 0, 11 us service, on time); the next 8
    // launch at 11.1 us and finish late (29 us > deadline: served, SLO
    // violated); the last 7 expire in the queue and are timeout-dropped.
    let cfg = SimConfig { deadline_ps: 20_000, ..base_cfg(&b) };
    let res = router::simulate(&cfg, &vec![100; 16]);
    assert_eq!(res.counters.served, 9, "{:?}", res.counters);
    assert_eq!(res.counters.slo_violations, 8);
    assert_eq!(res.counters.timed_out, 7);
    assert_eq!(res.counters.shed(), 0);
    assert!(res.counters.conserved());
}

#[test]
fn exhausted_retry_budget_sheds_typed() {
    let b = mock();
    // The only replica fails mid-batch with a zero retry budget: the
    // in-flight victim is shed as retries_exhausted, not retried into
    // the void and not dropped silently.
    let cfg = SimConfig { max_retries: 0, fail: Some((0, 5_000)), ..base_cfg(&b) };
    let res = router::simulate(&cfg, &[100]);
    assert_eq!(res.counters.served, 0);
    assert_eq!(res.counters.shed_retries, 1);
    assert_eq!(res.counters.retries, 0);
    assert_eq!(res.counters.failed_batches, 1);
    assert!(res.counters.conserved());
}

#[test]
fn failover_retries_onto_survivor_within_deadline() {
    let b = mock();
    // Two replicas; replica 0 fails 5 us into the first batch. The
    // victim retries with one backoff step (1 us) onto replica 1 and
    // completes at 17 us — well inside the 200 us deadline, so the
    // failover is SLO-clean and fully accounted.
    let cfg = SimConfig { replicas: 2, fail: Some((0, 5_000)), ..base_cfg(&b) };
    let res = router::simulate(&cfg, &[100]);
    assert_eq!(res.counters.served, 1);
    assert_eq!(res.counters.retries, 1);
    assert_eq!(res.counters.failovers, 1);
    assert_eq!(res.counters.failover_served, 1);
    assert_eq!(res.counters.failover_slo_ok, 1, "failover must land within the deadline budget");
    assert_eq!(res.counters.slo_violations, 0);
    assert_eq!(res.per_replica_served, vec![0, 1]);
    // fail at 5 us + 1 us backoff + 11 us single service, measured from
    // the original 0.1 us arrival.
    assert_eq!(res.latencies.max_ps(), 5_000 + 1_000 + b.batch_ps(1) - 100);
    assert!(res.counters.conserved());
}

// ---------------------------------------------------------------------
// Determinism gates
// ---------------------------------------------------------------------

/// CI's serving determinism gate: the full serve-bench report must be
/// byte-identical in its seed regardless of `--jobs`.
#[test]
fn serve_bench_report_is_bit_identical_across_jobs() {
    let backend = mock();
    let opts = ServeBenchOptions {
        requests: 128,
        queue_cap: 16,
        load_fracs: vec![0.3, 0.9, 1.8],
        fail_replica: Some((1, 0.5)),
        arrival: ArrivalProcess::parse("bursty").unwrap(),
        ..ServeBenchOptions::default()
    };
    let serial = run_serve_bench_on(&ServeBenchOptions { jobs: 1, ..opts.clone() }, &backend)
        .unwrap()
        .to_json();
    let parallel = run_serve_bench_on(&ServeBenchOptions { jobs: 4, ..opts.clone() }, &backend)
        .unwrap()
        .to_json();
    assert_eq!(serial, parallel, "serve-bench must be byte-identical across --jobs");
    let reseeded = run_serve_bench_on(&ServeBenchOptions { seed: opts.seed + 1, ..opts }, &backend)
        .unwrap()
        .to_json();
    assert_ne!(serial, reseeded, "the seed must actually steer the arrivals");
}

/// Property: under *any* sane configuration, a mid-run replica
/// hard-failure yields failover-or-typed-shed — never a panic, never a
/// lost request — and the same seed replays byte-for-byte.
#[test]
fn replica_hard_failure_is_failover_or_typed_shed_never_a_panic() {
    let backend = mock();
    miniprop::check("serving-failure-conserves", 0x5E21_FA11, |rng| {
        let replicas = 1 + rng.below(4) as usize;
        let policy = match rng.below(3) {
            0 => RouterPolicy::RoundRobin,
            1 => RouterPolicy::LeastLoaded,
            _ => RouterPolicy::CacheAffinity,
        };
        let opts = ServeBenchOptions {
            seed: rng.next_u64(),
            requests: 48,
            replicas,
            queue_cap: 1 + rng.below(24) as usize,
            deadline_ps: Some(20_000 + rng.below(400_000)),
            max_retries: rng.below(4) as u32,
            policy,
            load_fracs: vec![0.1 + rng.next_f64() * 2.4],
            fail_replica: Some((rng.below(replicas as u64) as usize, rng.next_f64())),
            ..ServeBenchOptions::default()
        };
        // The router asserts conservation internally; any violation or
        // panic fails the property with a replayable (case, seed) pair.
        let rep = run_serve_bench_on(&opts, &backend).unwrap();
        for p in &rep.points {
            assert!(p.counters.conserved(), "{:?}", p.counters);
            assert_eq!(
                p.counters.resolved(),
                opts.requests,
                "every offered request needs a typed resolution"
            );
        }
        let replay = run_serve_bench_on(&opts, &backend).unwrap();
        assert_eq!(rep.to_json(), replay.to_json(), "same seed must replay byte-for-byte");
    });
}

/// Property (ISSUE 10): under *any* recalibration policy — never,
/// fixed, threshold — with randomized accuracy SLOs, check cadence,
/// sensitive-traffic mix, and a mid-run hard failure layered on top,
/// conservation still holds, every request resolves typed, and the
/// report is byte-identical at `--jobs 1` vs `--jobs 4`. The router
/// itself asserts the stagger invariant (a recalibrating replica never
/// receives a dispatch: the launch guard refuses it and any completion
/// outside the planned drain panics), so this property sweeps the state
/// space those assertions watch.
#[test]
fn any_recal_policy_conserves_staggers_and_replays_across_jobs() {
    let backend = mock();
    miniprop::check("serving-recal-conserves", 0xD21F_7A11, |rng| {
        let replicas = 1 + rng.below(3) as usize;
        let policy = match rng.below(3) {
            0 => RecalPolicy::Never,
            // Serve-bench horizons are microseconds, so period/decay are
            // scaled to make windows actually trigger mid-run.
            1 => RecalPolicy::Fixed { period_ps: 1 + rng.below(400_000) },
            _ => RecalPolicy::Threshold { trigger: 0.90 + rng.next_f64() * 0.09 },
        };
        let slo = 0.5 + rng.next_f64() * 0.4;
        let recal = RecalConfig {
            // Steep decay: the proxy visibly drops within a ~1 ms run.
            model: AccuracyModel::Linear { decay_per_s: 1.0e5 + rng.next_f64() * 9.0e5 },
            slo,
            degrade_at: (slo + 1.0) / 2.0,
            sensitive_permille: rng.below(1001) as u32,
            policy,
            check_period_ps: 1 + rng.below(100_000),
            reprogram_ps: 1 + rng.below(50_000),
        };
        let opts = ServeBenchOptions {
            seed: rng.next_u64(),
            requests: 48,
            replicas,
            queue_cap: 1 + rng.below(24) as usize,
            deadline_ps: Some(20_000 + rng.below(400_000)),
            max_retries: rng.below(4) as u32,
            load_fracs: vec![0.1 + rng.next_f64() * 2.4],
            fail_replica: if rng.below(2) == 0 {
                Some((rng.below(replicas as u64) as usize, rng.next_f64()))
            } else {
                None
            },
            recal: Some(recal),
            ..ServeBenchOptions::default()
        };
        let rep = run_serve_bench_on(&ServeBenchOptions { jobs: 1, ..opts.clone() }, &backend)
            .unwrap();
        for p in &rep.points {
            assert!(p.counters.conserved(), "{:?}", p.counters);
            assert_eq!(
                p.counters.resolved(),
                opts.requests,
                "every offered request needs a typed resolution: {:?}",
                p.counters
            );
        }
        let par = run_serve_bench_on(&ServeBenchOptions { jobs: 4, ..opts }, &backend).unwrap();
        assert_eq!(
            rep.to_json(),
            par.to_json(),
            "recal-enabled serve-bench must be byte-identical across --jobs"
        );
    });
}

// ---------------------------------------------------------------------
// Trace-machine smoke: the honest backend end-to-end
// ---------------------------------------------------------------------

#[test]
fn trace_backend_serve_bench_end_to_end_with_failover() {
    let backend =
        TraceMachineBackend::build(&[256, 128, 64], SystemKind::HighPower, 4, 1).unwrap();
    let opts = ServeBenchOptions {
        requests: 32,
        max_batch: 4,
        load_fracs: vec![0.5, 1.2],
        fail_replica: Some((1, 0.5)),
        ..ServeBenchOptions::default()
    };
    let rep = run_serve_bench_on(&opts, &backend).unwrap();
    assert_eq!(rep.points.len(), 2);
    for p in &rep.points {
        assert!(p.counters.conserved(), "{:?}", p.counters);
        assert!(p.counters.served > 0);
        assert!(p.fail_at_ps.is_some());
    }
    // The MLP winner is analog, so the degraded remap exists and its
    // rejoin cost is no faster than healthy service.
    assert!(rep.degraded_desc.is_some(), "expected a degradable analog mapping");
    for (h, d) in rep.service_ps.iter().zip(&rep.degraded_service_ps) {
        assert!(d >= h);
    }
    assert!(backend.batch_ps(1) > 0);
    let json = rep.to_json();
    assert!(json.contains("\"failovers\""));
    assert!(json.contains("\"degraded_service_ps\""));
}
