//! Cross-layer validation: the PJRT-executed AOT artifacts (Layer 2/1)
//! against the Rust-side AIMClib checker (Layer 3) and the AOT-time
//! probes. Requires `make artifacts`; tests are skipped otherwise.

use alpine::aimclib::checker::{self, Matrix};
use alpine::runtime::{default_artifacts_dir, read_f32_bin, Runtime};

fn runtime() -> Option<Runtime> {
    let dir = default_artifacts_dir();
    if !dir.join("INDEX").exists() {
        eprintln!("skipping PJRT tests: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(&dir).expect("PJRT CPU client"))
}

#[test]
fn all_artifacts_probe_check() {
    let Some(rt) = runtime() else { return };
    for name in rt.available_models().unwrap() {
        let m = rt.load(&name).unwrap();
        let (max_abs, rel) = m.probe_check().unwrap();
        assert!(rel < 1e-5, "{name}: rel {rel} max_abs {max_abs}");
    }
}

#[test]
fn analog_mlp_matches_rust_checker() {
    // The Pallas kernel (executed via PJRT) and aimclib::checker must
    // implement the same signal chain. We reproduce layer 1 of the MLP
    // in the checker from the shipped weight bins and compare.
    let Some(rt) = runtime() else { return };
    let model = rt.load("mlp_analog_b1").unwrap();

    let x = read_f32_bin(&model.manifest.inputs[0].file).unwrap();
    let w1 = read_f32_bin(&model.manifest.params[0].file).unwrap();

    // Re-derive the AOT-time spec: scales are baked as constants in the
    // HLO, so recover them the same way aot.py computed them.
    let xm = Matrix::new(1, 1024, x.clone());
    let w1m = Matrix::new(1024, 1024, w1);

    // in_scale from probe, w_scale from the *quantized* w is not
    // recoverable from w_prog (noise applied); but the digital bundle
    // ships w_q.
    let dig = rt.load("mlp_digital_b1").unwrap();
    let w1q = read_f32_bin(&dig.manifest.params[0].file).unwrap();
    let w1qm = Matrix::new(1024, 1024, w1q);
    // Weight codes must be integers within the symmetric int8 range.
    assert!(w1qm.data.iter().all(|v| v.abs() <= 127.0 && *v == v.round()));

    // End-to-end: PJRT analog vs PJRT digital stay close (iso-accuracy).
    let ya = model.run(&[x.clone()]).unwrap();
    let yd = dig.run(&[x]).unwrap();
    let num: f64 = ya[0]
        .iter()
        .zip(&yd[0])
        .map(|(a, b)| ((a - b) * (a - b)) as f64)
        .sum();
    let den: f64 = yd[0].iter().map(|b| (b * b) as f64).sum();
    let rel = (num / den.max(1e-30)).sqrt();
    assert!(rel < 0.25, "analog/digital disagree: rel {rel}");

    // Sanity on the checker itself with the shipped tensors: noiseless
    // analog (w_q) with a calibrated spec tracks the digital result.
    let spec = checker::calibrate(&xm, &w1m, 256, 256);
    let y_checker = checker::aimc_mvm(&xm, &w1qm, &spec);
    assert_eq!(y_checker.cols, 1024);
    assert!(y_checker.data.iter().all(|v| v.is_finite()));
}

#[test]
fn lstm_state_threading_via_pjrt() {
    let Some(rt) = runtime() else { return };
    let lstm = rt.load("lstm256_analog").unwrap();
    let x = read_f32_bin(&lstm.manifest.inputs[0].file).unwrap();
    let mut h = vec![0.0f32; 256];
    let mut c = vec![0.0f32; 256];
    for _ in 0..3 {
        let out = lstm.run(&[x.clone(), h.clone(), c.clone()]).unwrap();
        assert_eq!(out.len(), 3, "(y, h, c) tuple");
        let y = &out[0];
        assert_eq!(y.len(), 50);
        let sum: f32 = y.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "softmax distribution, got sum {sum}");
        h = out[1].clone();
        c = out[2].clone();
        assert!(h.iter().all(|v| v.abs() <= 1.0 + 1e-5));
    }
}

#[test]
fn batch_variant_consistent_with_single() {
    // Row 0 of an 8-batch run must equal the 1-batch run on that row
    // (per-row independence of the tile model).
    let Some(rt) = runtime() else { return };
    let b1 = rt.load("mlp_analog_b1").unwrap();
    let b8 = rt.load("mlp_analog_b8").unwrap();
    let x1 = read_f32_bin(&b1.manifest.inputs[0].file).unwrap();
    // Build an 8-batch where row 0 is the b1 probe.
    let mut x8 = Vec::with_capacity(8 * 1024);
    for k in 0..8 {
        if k == 0 {
            x8.extend_from_slice(&x1);
        } else {
            x8.extend(x1.iter().map(|v| v * 0.5));
        }
    }
    let y1 = b1.run(&[x1]).unwrap();
    let y8 = b8.run(&[x8]).unwrap();
    // The two bundles are calibrated on their own probe batches, so the
    // quantization grids differ slightly; rows agree to grid resolution.
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for j in 0..1024 {
        let a = y1[0][j] as f64;
        let b = y8[0][j] as f64;
        num += (a - b) * (a - b);
        den += a * a;
    }
    let rel = (num / den.max(1e-30)).sqrt();
    assert!(rel < 0.05, "row-0 rel mismatch {rel}");
}

#[test]
fn cnn_tiny_probabilities() {
    let Some(rt) = runtime() else { return };
    for name in ["cnn_tiny_analog", "cnn_tiny_digital"] {
        let m = rt.load(name).unwrap();
        let x = read_f32_bin(&m.manifest.inputs[0].file).unwrap();
        let y = m.run(&[x]).unwrap();
        assert_eq!(y[0].len(), 10);
        let sum: f32 = y[0].iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "{name}: {sum}");
        assert!(y[0].iter().all(|v| *v >= 0.0));
    }
}

#[test]
fn analog_and_digital_cnn_agree_on_argmax() {
    let Some(rt) = runtime() else { return };
    let a = rt.load("cnn_tiny_analog").unwrap();
    let d = rt.load("cnn_tiny_digital").unwrap();
    let x = read_f32_bin(&a.manifest.inputs[0].file).unwrap();
    let ya = a.run(&[x.clone()]).unwrap();
    let yd = d.run(&[x]).unwrap();
    let am = ya[0].iter().enumerate().max_by(|p, q| p.1.partial_cmp(q.1).unwrap()).unwrap().0;
    let dm = yd[0].iter().enumerate().max_by(|p, q| p.1.partial_cmp(q.1).unwrap()).unwrap().0;
    assert_eq!(am, dm, "analog and digital CNN should classify alike");
}
