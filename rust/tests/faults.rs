//! The fault-injection gate: the fault-free path must stay
//! **bit-identical** to the pre-fault simulator for every paper
//! workload case, faulty runs must be deterministic in the scenario
//! seed at any `--jobs N`, and an injected hard tile failure must
//! always end in a degraded remap or a typed [`RunError`] — never a
//! panic. CI runs this file as the `fault-determinism` job.

use alpine::config::{SystemConfig, SystemKind};
use alpine::coordinator::faults::{run_scenario, FaultScenarioOptions};
use alpine::coordinator::{run_workload, RunOptions};
use alpine::nn::{CnnVariant, LayerGraph};
use alpine::sim::machine::Machine;
use alpine::sim::{RunError, TileDriftSpec, TileFaultModel};
use alpine::workload::automap::{self, CostModel, SearchOptions, TopologyBudget};
use alpine::workload::cnn::{self, CnnCase};
use alpine::workload::lstm::{self, LstmCase};
use alpine::workload::mlp::{self, MlpCase};
use alpine::workload::transformer::{self, TransformerCase, TransformerShape};
use alpine::workload::{compile, Workload};
use alpine::util::miniprop;

/// Simulate `w` twice — once on the untouched machine, once with
/// explicit (but inactive) `TileFaultModel::none()` *and*
/// `TileDriftSpec::none()` hooks attached to every tile — and require
/// bit-identical statistics. This pins the promise that merely *having*
/// the fault and drift hooks compiled in changes nothing on the
/// drift-free path (the ISSUE-10 acceptance gate).
fn check_fault_free_identity(cfg: &SystemConfig, w: &Workload) {
    let pristine = Machine::new(cfg.clone(), w.spec.clone())
        .run(w.traces.clone())
        .unwrap();
    let mut hooked = Machine::new(cfg.clone(), w.spec.clone());
    for t in 0..w.spec.tiles.len() {
        hooked.set_tile_fault(t, TileFaultModel::none());
        hooked.set_tile_drift(t, TileDriftSpec::none());
    }
    assert!(!hooked.has_tile_faults(), "none() must not count as a fault");
    assert!(!hooked.has_tile_drift(), "none() must not count as drift");
    let hooked = hooked.run(w.traces.clone()).unwrap();
    hooked.assert_bit_identical(&pristine, &w.label);
}

#[test]
fn mlp_cases_fault_free_bit_identical() {
    let cfg = SystemConfig::high_power();
    for case in [
        MlpCase::Digital { cores: 1 },
        MlpCase::Digital { cores: 2 },
        MlpCase::Digital { cores: 4 },
        MlpCase::Analog { case: 1 },
        MlpCase::Analog { case: 2 },
        MlpCase::Analog { case: 3 },
        MlpCase::Analog { case: 4 },
        MlpCase::AnalogLoose,
    ] {
        let w = mlp::generate(case, &cfg, 24).unwrap();
        check_fault_free_identity(&cfg, &w);
    }
}

#[test]
fn lstm_cases_fault_free_bit_identical() {
    let cfg = SystemConfig::high_power();
    for case in [
        LstmCase::Digital { cores: 1 },
        LstmCase::Digital { cores: 2 },
        LstmCase::Digital { cores: 5 },
        LstmCase::Analog { case: 1 },
        LstmCase::Analog { case: 2 },
        LstmCase::Analog { case: 3 },
        LstmCase::Analog { case: 4 },
    ] {
        let w = lstm::generate(case, 256, &cfg, 16).unwrap();
        check_fault_free_identity(&cfg, &w);
    }
    let lp = SystemConfig::for_kind(SystemKind::LowPower);
    let w = lstm::generate(LstmCase::Analog { case: 3 }, 512, &lp, 16).unwrap();
    check_fault_free_identity(&lp, &w);
}

#[test]
fn cnn_cases_fault_free_bit_identical() {
    let cfg = SystemConfig::high_power();
    for case in [CnnCase::Digital, CnnCase::Analog] {
        let w = cnn::generate(case, CnnVariant::Fast, &cfg, 12).unwrap();
        check_fault_free_identity(&cfg, &w);
    }
}

#[test]
fn transformer_cases_fault_free_bit_identical() {
    let cfg = SystemConfig::high_power();
    let shape = TransformerShape::new(64, 2, 16, 1, 128).unwrap();
    for case in [TransformerCase::Digital, TransformerCase::Analog] {
        let w = transformer::generate(shape, case, 24).unwrap();
        check_fault_free_identity(&cfg, &w);
    }
}

/// The coordinator-level drift hook (`RunOptions::with_drift`): inactive
/// specs are bit-identical to no specs, and an *active* spec still
/// changes nothing about timing or energy — conductance drift degrades
/// only the accuracy proxy, never the simulated clock.
#[test]
fn run_options_drift_hooks_never_change_timing() {
    let cfg = SystemConfig::high_power();
    let mk = || mlp::generate(MlpCase::Analog { case: 1 }, &cfg, 8).unwrap();
    let w = mk();
    let n = w.spec.tiles.len();
    assert!(n > 0, "analog MLP must place tiles");
    let base = run_workload(SystemKind::HighPower, w, &RunOptions::default()).unwrap();

    let none: Vec<_> = (0..n).map(|t| (t, TileDriftSpec::none())).collect();
    let hooked =
        run_workload(SystemKind::HighPower, mk(), &RunOptions::with_drift(none)).unwrap();
    assert_eq!(base.time_s.to_bits(), hooked.time_s.to_bits());
    assert_eq!(base.energy.total_j().to_bits(), hooked.energy.total_j().to_bits());

    let active: Vec<_> = (0..n)
        .map(|t| (t, TileDriftSpec { nu_ppm: 50_000, nu_sigma_ppm: 20_000, seed: 7 }))
        .collect();
    let drifted =
        run_workload(SystemKind::HighPower, mk(), &RunOptions::with_drift(active)).unwrap();
    assert_eq!(base.time_s.to_bits(), drifted.time_s.to_bits());
    assert_eq!(base.energy.total_j().to_bits(), drifted.energy.total_j().to_bits());
}

// ---------------------------------------------------------------------
// Scenario determinism
// ---------------------------------------------------------------------

/// Same seed ⇒ bit-identical faulty sweep, regardless of worker count.
#[test]
fn faulty_scenario_is_bit_identical_across_jobs() {
    let opts = |jobs| FaultScenarioOptions {
        steps: 3,
        n_inf: 2,
        jobs,
        fail_tile: Some((0, 0)),
        ..FaultScenarioOptions::default()
    };
    let serial = run_scenario(&opts(1)).unwrap();
    let parallel = run_scenario(&opts(4)).unwrap();

    assert_eq!(serial.desc, parallel.desc);
    assert_eq!(serial.curve.len(), parallel.curve.len());
    for (a, b) in serial.curve.iter().zip(&parallel.curve) {
        assert_eq!(a.intensity.to_bits(), b.intensity.to_bits());
        assert_eq!(a.stall_ps, b.stall_ps);
        assert_eq!(a.mse.to_bits(), b.mse.to_bits(), "mse at x={}", a.intensity);
        assert_eq!(a.top1_agreement.to_bits(), b.top1_agreement.to_bits());
        assert_eq!(a.time_s.to_bits(), b.time_s.to_bits(), "time at x={}", a.intensity);
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
    }
    let (fa, fb) = (serial.failure.unwrap(), parallel.failure.unwrap());
    assert_eq!(fa.degraded_desc, fb.degraded_desc);
    assert_eq!(fa.remapped_anchors, fb.remapped_anchors);
    assert_eq!(fa.healthy.time_s.to_bits(), fb.healthy.time_s.to_bits());
    assert_eq!(fa.degraded.time_s.to_bits(), fb.degraded.time_s.to_bits());
}

// ---------------------------------------------------------------------
// Property: hard failure never panics
// ---------------------------------------------------------------------

/// Injecting a hard tile failure at *any* (tile, cycle) into an analog
/// workload either completes, or surfaces a typed `RunError::TileFailed`
/// — and the degradation pass always produces a CPU-fallback remap for
/// any tile the mapping occupies. `miniprop::check` fails the property
/// on any panic, so this is also the zero-panic gate.
#[test]
fn hard_tile_failure_is_typed_or_degraded_never_a_panic() {
    let cfg = SystemConfig::high_power();
    let graph = LayerGraph::mlp(&[256, 128, 64]);
    let budget = TopologyBudget::for_config(&cfg);
    let outcome = automap::search_opts(
        &graph,
        &budget,
        &cfg,
        &SearchOptions {
            top_k: 2,
            model: CostModel::Compositional,
            cap: None,
            max_depth: 4,
            max_replica: 2,
            jobs: 1,
            compile_cache: true,
        },
    )
    .unwrap();
    let best = &outcome.ranked[0];
    let w = compile::compile(&graph, &best.mapping, 2).unwrap();
    let n_tiles = w.spec.tiles.len();
    assert!(n_tiles > 0, "best candidate should use analog tiles");

    miniprop::check("hard-tile-failure-never-panics", 0xFA_17, |rng| {
        let tile = rng.below(n_tiles as u64) as usize;
        let fail_at_ps = rng.below(2_000_000);
        let model = TileFaultModel {
            hard_fail_at_ps: Some(fail_at_ps),
            ..TileFaultModel::none()
        };
        let w = compile::compile(&graph, &best.mapping, 2).unwrap();
        match run_workload(SystemKind::HighPower, w, &RunOptions::with_faults(vec![(tile, model)])) {
            Ok(r) => assert!(r.time_s > 0.0),
            Err(RunError::TileFailed { tile: t, .. }) => assert_eq!(t, tile),
            Err(e) => panic!("unexpected error kind: {e}"),
        }

        // The degradation pass must remap any occupied tile cleanly.
        let occupied: Vec<usize> = (0..n_tiles)
            .filter(|&t| automap::degrade_mapping(&graph, &best.mapping, t, &budget).is_ok())
            .collect();
        assert!(!occupied.is_empty(), "no tile of the best mapping is degradable");
        let pick = occupied[rng.below(occupied.len() as u64) as usize];
        let d = automap::degrade_mapping(&graph, &best.mapping, pick, &budget).unwrap();
        assert!(!d.remapped_anchors.is_empty());
    });
}
