//! The DAG-workload gate: fork/join graph validation must return typed
//! errors (never panic), the three deliverable graphs — residual block,
//! parallel-head transformer encoder, mixture-of-experts — must
//! compile, automap and simulate end-to-end, and random fork/join
//! graphs must either run self-consistently or fail with a typed
//! [`WorkloadError`]. Imports go through `alpine::prelude` on purpose:
//! this file is also the compile-time check that the prelude covers the
//! whole graph-to-simulation flow.

use alpine::prelude::*;
use alpine::util::miniprop;

fn budget() -> TopologyBudget {
    TopologyBudget { cores: 4, tiles: 12, tile_rows: 256, tile_cols: 256, channels: 64 }
}

// ---------------------------------------------------------------------
// Validation errors
// ---------------------------------------------------------------------

#[test]
fn cycle_is_detected() {
    let mut g = LayerGraph::new("cyclic");
    let i = g.add(LayerKind::Input { bytes: 32, marshal_insts: 10, raw_bytes: 8 });
    let m = g.add(LayerKind::Merge { op: MergeOp::Add, elems: 8 });
    let d = g.add(LayerKind::Dense { rows: 8, cols: 8, weight_slot: 0 });
    let o = g.add(LayerKind::Output { bytes: 32 });
    g.edges.push((i, m));
    g.edges.push((m, d));
    g.edges.push((d, m)); // back edge: m -> d -> m
    g.edges.push((d, o));
    assert!(matches!(g.validate(), Err(GraphError::Cycle { .. })), "{:?}", g.validate());
}

#[test]
fn join_shape_mismatch_is_detected() {
    let mut b = GraphBuilder::new("bad-join");
    let input = b.input(32, 10, 8);
    let d1 = b.layer(LayerKind::Dense { rows: 8, cols: 8, weight_slot: 0 }).after(&[input]);
    let d2 = b.layer(LayerKind::Dense { rows: 8, cols: 12, weight_slot: 1 }).after(&[input]);
    let m = b.layer(LayerKind::Merge { op: MergeOp::Add, elems: 8 }).after(&[d1, d2]);
    b.layer(LayerKind::Output { bytes: 32 }).after(&[m]);
    let err = b.finish().unwrap_err();
    assert!(
        matches!(err, GraphError::JoinShapeMismatch { expected: 8, got: 12, .. }),
        "{err:?}"
    );
}

#[test]
fn dangling_fork_branch_is_detected() {
    let mut b = GraphBuilder::new("dangling");
    let input = b.input(32, 10, 8);
    let d1 = b.layer(LayerKind::Dense { rows: 8, cols: 8, weight_slot: 0 }).after(&[input]);
    let d2 = b.layer(LayerKind::Dense { rows: 8, cols: 8, weight_slot: 1 }).after(&[input]);
    b.layer(LayerKind::Output { bytes: 32 }).after(&[d1]);
    let err = b.finish().unwrap_err();
    assert!(matches!(err, GraphError::DanglingFork { node } if node == d2), "{err:?}");
}

// ---------------------------------------------------------------------
// Deliverable graphs, end to end
// ---------------------------------------------------------------------

fn deliverables() -> Vec<LayerGraph> {
    vec![
        LayerGraph::resnet_block(8, 4, 10),
        LayerGraph::transformer_parallel(16, 2, 8, 1, 32),
        LayerGraph::moe(64, 32, 4, 2, 10),
    ]
}

#[test]
fn deliverable_graphs_validate() {
    for g in deliverables() {
        g.validate().unwrap_or_else(|e| panic!("{}: {e}", g.name));
    }
}

/// Each deliverable graph must automap to a feasible mapping, compile,
/// and simulate to a nonzero runtime with analog activity — the full
/// DAG path through search, compiler and trace machine.
#[test]
fn deliverable_graphs_simulate_end_to_end() {
    let cfg = SystemConfig::high_power();
    for g in deliverables() {
        let out = search(&g, &budget(), &cfg, 2).unwrap_or_else(|e| panic!("{}: {e}", g.name));
        assert!(out.feasible > 0, "{}: no feasible mapping", g.name);
        let best = &out.ranked[0];
        validate(&g, &best.mapping).unwrap_or_else(|e| panic!("{}: {e}", g.name));
        let w = compile(&g, &best.mapping, 3).unwrap_or_else(|e| panic!("{}: {e}", g.name));
        let r = run_workload(SystemKind::HighPower, w, &RunOptions::default())
            .unwrap_or_else(|e| panic!("{}: {e}", g.name));
        assert!(r.time_s > 0.0, "{}", g.name);
        assert!(r.aimc_processes > 0, "{}: expected analog MVMs", g.name);
    }
}

/// Nested steady-state fast-forward must be invisible on DAG workloads:
/// forcing it off reproduces bit-identical runtimes.
#[test]
fn dag_runs_identical_without_nested_fast_forward() {
    let cfg = SystemConfig::high_power();
    for g in deliverables() {
        let out = search(&g, &budget(), &cfg, 1).unwrap();
        let w = |n| compile(&g, &out.ranked[0].mapping, n).unwrap();
        let fast = run_workload(SystemKind::HighPower, w(8), &RunOptions::default()).unwrap();
        let slow = run_workload(
            SystemKind::HighPower,
            w(8),
            &RunOptions { nested_ff: Some(false), ..RunOptions::default() },
        )
        .unwrap();
        assert_eq!(fast.time_s.to_bits(), slow.time_s.to_bits(), "{}", g.name);
        assert_eq!(fast.total_insts, slow.total_insts, "{}", g.name);
        assert_eq!(fast.aimc_processes, slow.aimc_processes, "{}", g.name);
    }
}

// ---------------------------------------------------------------------
// Property: random fork/join graphs never panic
// ---------------------------------------------------------------------

/// Random fork/join graphs — some deliberately malformed — must either
/// make it through search + compile + simulation self-consistently, or
/// fail with a typed [`GraphError`] / [`WorkloadError`]. `miniprop`
/// converts any panic into a reproducible failure, so this is the
/// zero-panic gate of the DAG path (CI: determinism job).
#[test]
fn random_fork_join_graphs_compile_or_fail_typed() {
    let cfg = SystemConfig::high_power();
    miniprop::check("dag-never-panics", 0xDA6, |rng| {
        let w_in = 4 * (1 + rng.below(4)); // 4..=16
        let mut b = GraphBuilder::new("rand-dag");
        let input = b.input(4 * w_in, 10, w_in);
        let mut slot = 0;
        let mut dense = |b: &mut GraphBuilder, pred: NodeId, rows: u64, cols: u64| {
            slot += 1;
            b.layer(LayerKind::Dense { rows, cols, weight_slot: slot - 1 }).after(&[pred])
        };
        let trunk_w = 4 * (1 + rng.below(4));
        let trunk = dense(&mut b, input, w_in, trunk_w);

        // Fork 2-3 branches, each one Dense (sometimes with a ReLU).
        let n_branches = 2 + rng.below(2) as usize;
        let branch_w = 4 * (1 + rng.below(4));
        let mut branches = Vec::new();
        let mut widths = Vec::new();
        for _ in 0..n_branches {
            let mut n = dense(&mut b, trunk, trunk_w, branch_w);
            if rng.below(2) == 0 {
                n = b
                    .layer(LayerKind::Activation { kind: ActKind::Relu, elems: branch_w })
                    .after(&[n]);
            }
            branches.push(n);
            widths.push(branch_w);
        }

        // Join: Add (equal widths) or Concat (sum) — 1 in 4 cases gets a
        // deliberately wrong width to exercise the typed-error path.
        let (op, mut elems) = if rng.below(2) == 0 {
            (MergeOp::Add, branch_w)
        } else {
            (MergeOp::Concat, widths.iter().sum::<u64>())
        };
        if rng.below(4) == 0 {
            elems += 4; // malformed join on purpose
        }
        let merge = b.layer(LayerKind::Merge { op, elems }).after(&branches);
        let head = dense(&mut b, merge, elems, 8);
        b.layer(LayerKind::Output { bytes: 32 }).after(&[head]);

        let graph = match b.finish() {
            Ok(g) => g,
            Err(_) => return, // typed GraphError — exactly what malformed cases should hit
        };
        let out = match alpine::workload::automap::search_opts(
            &graph,
            &budget(),
            &cfg,
            &SearchOptions { top_k: 1, cap: Some(40), max_depth: 2, ..SearchOptions::default() },
        ) {
            Ok(o) => o,
            Err(WorkloadError::InvalidGraph(_)) | Err(WorkloadError::InvalidMapping(_)) => return,
            Err(e) => panic!("unexpected error kind: {e}"),
        };
        if out.ranked.is_empty() {
            return; // nothing feasible under the tiny budget — fine
        }
        let w = compile(&graph, &out.ranked[0].mapping, 2).unwrap();
        let r = run_workload(SystemKind::HighPower, w, &RunOptions::default()).unwrap();
        assert!(r.time_s > 0.0);
    });
}
