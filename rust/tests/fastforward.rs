//! The fast-forward equivalence gate: steady-state fast-forward — both
//! the flat single-level detector and the PR-7 nested per-segment one —
//! must produce **bit-identical** `RunStats` to full op-by-op replay,
//! for every paper workload case (MLP / LSTM / CNN / transformer) and
//! for random multi-core trace programs with channels, mutexes and
//! tiles (the `machine-fastforward-equivalence` property). CI runs this
//! file as part of the determinism gate.

use alpine::config::{SystemConfig, SystemKind};
use alpine::isa::InstClass;
use alpine::nn::CnnVariant;
use alpine::sim::machine::{ChannelSpec, Machine, MachineSpec, TileSpec};
use alpine::sim::{Coupling, Placement, TileDriftSpec, TileFaultModel};
use alpine::stats::RunStats;
use alpine::util::miniprop;
use alpine::util::rng::Rng;
use alpine::workload::cnn::{self, CnnCase};
use alpine::workload::lstm::{self, LstmCase};
use alpine::workload::mlp::{self, MlpCase};
use alpine::workload::trace::{Segment, TraceBuilder, TraceOp};
use alpine::workload::transformer::{self, TransformerCase, TransformerShape};
use alpine::workload::Workload;

// The exhaustive field-destructuring comparison lives on RunStats
// itself (`assert_bit_identical`), so a future stats field cannot be
// silently excluded from this gate.

/// Run a compiled workload with fast-forward and nested (per-segment)
/// fast-forward toggled independently; returns the stats and the number
/// of closed-form jumps taken.
fn run_with(cfg: &SystemConfig, w: &Workload, ff: bool, nested: bool) -> (RunStats, u32) {
    let mut m = Machine::new(cfg.clone(), w.spec.clone());
    m.set_fast_forward(ff);
    m.set_nested_fast_forward(nested);
    let rs = m.run(w.traces.clone()).unwrap();
    (rs, m.fast_forward_jumps())
}

/// Three-way check: nested fast-forward (the PR-7 default), flat
/// single-level fast-forward (the PR-4 behaviour), and full replay must
/// all produce bit-identical stats.
fn check_case(cfg: &SystemConfig, w: &Workload) -> u32 {
    let (nested, jumps) = run_with(cfg, w, true, true);
    let (flat, _) = run_with(cfg, w, true, false);
    let (reference, ref_jumps) = run_with(cfg, w, false, false);
    assert_eq!(ref_jumps, 0, "{}: knob off must fully replay", w.label);
    nested.assert_bit_identical(&reference, &w.label);
    flat.assert_bit_identical(&reference, &w.label);
    jumps
}

#[test]
fn mlp_cases_fastforward_bit_identical() {
    let cfg = SystemConfig::high_power();
    let mut total_jumps = 0;
    for case in [
        MlpCase::Digital { cores: 1 },
        MlpCase::Digital { cores: 2 },
        MlpCase::Digital { cores: 4 },
        MlpCase::Analog { case: 1 },
        MlpCase::Analog { case: 2 },
        MlpCase::Analog { case: 3 },
        MlpCase::Analog { case: 4 },
        MlpCase::AnalogLoose,
    ] {
        let w = mlp::generate(case, &cfg, 24).unwrap();
        let jumps = check_case(&cfg, &w);
        if case == (MlpCase::Digital { cores: 1 }) {
            assert!(jumps >= 1, "{}: fast-forward never engaged", w.label);
        }
        total_jumps += jumps;
    }
    assert!(total_jumps >= 1, "no MLP case fast-forwarded at all");
}

#[test]
fn lstm_cases_fastforward_bit_identical() {
    let cfg = SystemConfig::high_power();
    for case in [
        LstmCase::Digital { cores: 1 },
        LstmCase::Digital { cores: 2 },
        LstmCase::Digital { cores: 5 },
        LstmCase::Analog { case: 1 },
        LstmCase::Analog { case: 2 },
        LstmCase::Analog { case: 3 },
        LstmCase::Analog { case: 4 },
    ] {
        let w = lstm::generate(case, 256, &cfg, 16).unwrap();
        check_case(&cfg, &w);
    }
    // One larger size on the low-power system for coverage.
    let lp = SystemConfig::for_kind(SystemKind::LowPower);
    let w = lstm::generate(LstmCase::Analog { case: 3 }, 512, &lp, 16).unwrap();
    check_case(&lp, &w);
}

#[test]
fn cnn_cases_fastforward_bit_identical() {
    let cfg = SystemConfig::high_power();
    for case in [CnnCase::Digital, CnnCase::Analog] {
        let w = cnn::generate(case, CnnVariant::Fast, &cfg, 12).unwrap();
        // PR-7 structural guarantee: the digital CNN's per-row stream
        // loops survive *inside* the inference loop as a nested
        // `Segment::Loop` program — the shape the hierarchical
        // fast-forward exists for.
        if matches!(case, CnnCase::Digital) {
            assert!(
                w.traces
                    .iter()
                    .any(|t| t.segments.iter().any(|s| matches!(s, Segment::Loop { .. }))),
                "digital CNN trace lost its nested Loop structure"
            );
        }
        check_case(&cfg, &w);
    }
}

#[test]
fn transformer_cases_fastforward_bit_identical() {
    let cfg = SystemConfig::high_power();
    let shape = TransformerShape::new(64, 2, 16, 1, 128).unwrap();
    for case in [TransformerCase::Digital, TransformerCase::Analog] {
        let w = transformer::generate(shape, case, 24).unwrap();
        check_case(&cfg, &w);
    }
}

// ---------------------------------------------------------------------
// Time-dependent fault models vs the closed-form clock (ISSUE 10)
// ---------------------------------------------------------------------

/// Pinned guard: a time-dependent fault model may never race the
/// fast-forward clock. Two legal outcomes, one per model class:
///
/// * **transient stalls** are phased against absolute time, so the
///   machine must refuse to jump at all (`jumps == 0`) — and the run
///   stays bit-identical to replay trivially;
/// * **conductance drift** is accuracy-only (age = `now -
///   programmed_at`, both advanced consistently by a jump), so the
///   machine must keep jumping exactly as the pristine run does AND
///   stay bit-identical to full op-by-op replay with the same spec.
#[test]
fn time_dependent_fault_models_never_race_fast_forward() {
    let cfg = SystemConfig::high_power();
    let spec = MachineSpec {
        tiles: vec![TileSpec { rows: 256, cols: 256, coupling: Coupling::Tight }],
        ..Default::default()
    };
    // Maximally periodic single-core tile pipeline: the steady-state
    // detector must engage on the pristine run.
    let mut b = TraceBuilder::new();
    b.push(TraceOp::CmInit {
        tile: 0,
        placement: Placement { row0: 0, col0: 0, rows: 256, cols: 256 },
    });
    b.repeat(48, |b, _| {
        b.compute(InstClass::IntAlu, 1_000);
        b.push(TraceOp::CmQueue { tile: 0, bytes: 128 });
        b.push(TraceOp::CmProcess { tile: 0 });
        b.push(TraceOp::CmDequeue { tile: 0, bytes: 128 });
    });
    let trace = b.build_trace();

    let run = |ff: bool, drift: Option<TileDriftSpec>, fault: Option<TileFaultModel>| {
        let mut m = Machine::new(cfg.clone(), spec.clone());
        m.set_fast_forward(ff);
        m.set_nested_fast_forward(ff);
        if let Some(d) = drift {
            m.set_tile_drift(0, d);
        }
        if let Some(f) = fault {
            m.set_tile_fault(0, f);
        }
        let rs = m.run(vec![trace.clone()]).unwrap();
        (rs, m.fast_forward_jumps())
    };

    let (clean_ff, clean_jumps) = run(true, None, None);
    let (clean_replay, _) = run(false, None, None);
    clean_ff.assert_bit_identical(&clean_replay, "ff-guard/pristine");
    assert!(clean_jumps >= 1, "pristine periodic tile loop must fast-forward");

    // Transient stall windows: ff is disabled outright.
    let fault = TileFaultModel {
        transient_period_ps: 400_000,
        transient_stall_ps: 60_000,
        ..TileFaultModel::none()
    };
    let (faulty_ff, fault_jumps) = run(true, None, Some(fault));
    assert_eq!(fault_jumps, 0, "transient fault model must disable fast-forward");
    let (faulty_replay, _) = run(false, None, Some(fault));
    faulty_ff.assert_bit_identical(&faulty_replay, "ff-guard/transient");

    // Active drift: ff keeps jumping and stays bit-identical to replay.
    let drift = TileDriftSpec { nu_ppm: 50_000, nu_sigma_ppm: 20_000, seed: 0xD81F };
    let (drift_ff, drift_jumps) = run(true, Some(drift), None);
    assert_eq!(
        drift_jumps, clean_jumps,
        "drift is accuracy-only and must not perturb the ff schedule"
    );
    let (drift_replay, replay_jumps) = run(false, Some(drift), None);
    assert_eq!(replay_jumps, 0);
    drift_ff.assert_bit_identical(&drift_replay, "ff-guard/drift");
    // The drift sensor agrees between the jumped and replayed clocks.
    let probe_ps = 10 * clean_replay.roi_time_ps.max(1);
    let mut m = Machine::new(cfg.clone(), spec.clone());
    m.set_tile_drift(0, drift);
    let h = m.tile_health(0, probe_ps);
    assert_eq!(h.age_ps, probe_ps, "fresh tile ages from its programming timestamp");
    assert!(h.drift_factor <= 1.0);
}

// ---------------------------------------------------------------------
// Property: random multi-core looped workloads
// ---------------------------------------------------------------------

/// Abstract per-iteration op recipe — generated once per core so every
/// `Rep` iteration emits the same op skeleton (only addresses may
/// advance with the iteration index).
#[derive(Clone, Copy)]
enum RecipeOp {
    Compute { insts: u64 },
    /// Fixed-address stream (weights-like: re-read every iteration).
    StreamFixed { base: u64, bytes: u64, write: bool },
    /// Fresh per-iteration stream (inputs/outputs-like: base advances).
    StreamFresh { base: u64, bytes: u64, stride: u64, write: bool },
    /// queue -> process -> dequeue on the core-private tile.
    Tile { bytes: u64 },
    /// lock -> short burst -> unlock on the shared mutex.
    Mutex { insts: u64 },
}

fn emit_recipe(b: &mut TraceBuilder, core: usize, ops: &[RecipeOp], k: u32) {
    for op in ops {
        match *op {
            RecipeOp::Compute { insts } => {
                b.compute(InstClass::IntAlu, insts);
            }
            RecipeOp::StreamFixed { base, bytes, write } => {
                if write {
                    b.stream_write(base, bytes, 2);
                } else {
                    b.stream_read(base, bytes, 2);
                }
            }
            RecipeOp::StreamFresh { base, bytes, stride, write } => {
                let at = base + k as u64 * stride;
                if write {
                    b.stream_write(at, bytes, 2);
                } else {
                    b.stream_read(at, bytes, 2);
                }
            }
            RecipeOp::Tile { bytes } => {
                b.push(TraceOp::CmQueue { tile: core, bytes });
                b.push(TraceOp::CmProcess { tile: core });
                b.push(TraceOp::CmDequeue { tile: core, bytes });
            }
            RecipeOp::Mutex { insts } => {
                b.push(TraceOp::MutexLock { id: 0 });
                b.compute(InstClass::SimdOp, insts);
                b.push(TraceOp::MutexUnlock { id: 0 });
            }
        }
    }
}

fn random_recipe(rng: &mut Rng, core: usize, with_tile: bool) -> Vec<RecipeOp> {
    let n = 1 + rng.below(4) as usize;
    let mut ops = Vec::with_capacity(n);
    for _ in 0..n {
        ops.push(match rng.below(if with_tile { 5 } else { 4 }) {
            0 => RecipeOp::Compute { insts: 200 + rng.below(4000) },
            1 => RecipeOp::StreamFixed {
                base: 0x1000_0000 + core as u64 * 0x0400_0000 + rng.below(8) * 0x1_0000,
                bytes: (1 + rng.below(64)) * 64,
                write: rng.below(4) == 0,
            },
            2 => RecipeOp::StreamFresh {
                base: 0x8000_0000 + core as u64 * 0x1000_0000,
                bytes: (1 + rng.below(32)) * 64,
                stride: (1 + rng.below(64)) * 64,
                write: rng.below(2) == 0,
            },
            3 => RecipeOp::Mutex { insts: 50 + rng.below(500) },
            _ => RecipeOp::Tile { bytes: 1 + rng.below(256) },
        });
    }
    ops
}

/// Random multi-core pipelines (chain of channels, shared mutex,
/// core-private tiles, fixed + per-iteration-fresh streams) must
/// simulate bit-identically with fast-forward on and off.
#[test]
fn machine_fastforward_equivalence() {
    miniprop::check("machine-fastforward-equivalence", 0xFF_2024, |rng| {
        let n_cores = 2 + rng.below(2) as usize; // 2..3
        let iters = 16 + rng.below(48) as u32;
        let with_tiles = rng.below(2) == 0;
        let spec = MachineSpec {
            tiles: if with_tiles {
                (0..n_cores)
                    .map(|_| TileSpec { rows: 256, cols: 256, coupling: Coupling::Tight })
                    .collect()
            } else {
                Vec::new()
            },
            mutexes: 1,
            channels: (0..n_cores - 1)
                .map(|c| ChannelSpec { producer: c, consumer: c + 1, capacity: 2 })
                .collect(),
        };
        let msg_bytes: Vec<u64> = (0..n_cores - 1).map(|_| (1 + rng.below(16)) * 64).collect();

        let mut traces = Vec::with_capacity(n_cores);
        for core in 0..n_cores {
            let recipe = random_recipe(rng, core, with_tiles);
            let mut b = TraceBuilder::new();
            if with_tiles {
                b.push(TraceOp::CmInit {
                    tile: core,
                    placement: Placement { row0: 0, col0: 0, rows: 256, cols: 256 },
                });
            }
            // Optional non-looped prologue.
            if rng.below(2) == 0 {
                b.compute(InstClass::IntAlu, 100 + rng.below(2000));
            }
            let recv_ch = core.checked_sub(1);
            let send_ch = (core + 1 < n_cores).then_some(core);
            let bytes = msg_bytes.clone();
            b.repeat(iters, |b, k| {
                if let Some(ch) = recv_ch {
                    b.push(TraceOp::Recv { ch });
                }
                emit_recipe(b, core, &recipe, k);
                if let Some(ch) = send_ch {
                    // Fixed buffer address: iteration-invariant and
                    // therefore affine-encodable.
                    b.push(TraceOp::Send {
                        ch,
                        bytes: bytes[ch],
                        addr: 0xB000_0000 + ch as u64 * 0x0010_0000,
                    });
                }
            });
            traces.push(b.build_trace());
        }

        let run = |ff: bool, nested: bool| {
            let mut m = Machine::new(SystemConfig::high_power(), spec.clone());
            m.set_fast_forward(ff);
            m.set_nested_fast_forward(nested);
            m.run(traces.clone()).unwrap()
        };
        let nested = run(true, true);
        let flat = run(true, false);
        let reference = run(false, false);
        nested.assert_bit_identical(&reference, "machine-fastforward-equivalence");
        flat.assert_bit_identical(&reference, "machine-fastforward-equivalence/flat");
    });
}
