//! Property tests of the mapping compiler: for random small layer
//! graphs + random valid mappings, the emitted `MachineSpec` must be
//! self-consistent (every trace tile/mutex/channel index is declared,
//! every channel is driven only by its declared producer/consumer
//! cores, ROI markers balance) and the machine must run the compiled
//! workload to completion without deadlock.

use alpine::config::SystemConfig;
use alpine::nn::{ActKind, LayerGraph, LayerKind, NodeId};
use alpine::sim::aimc::{Coupling, Placement};
use alpine::sim::machine::{Machine, TileSpec};
use alpine::util::miniprop;
use alpine::util::rng::Rng;
use alpine::workload::compile::mapping::{
    Handoff, Mapping, Place, SplitKind, Stage, StageInput, StageOutput, Step, TilePlacement,
};
use alpine::workload::compile::{compile, CHANNEL_CAPACITY};
use alpine::workload::trace::TraceOp;
use alpine::workload::Workload;

/// One random layer block: a Dense plus a random elementwise tail.
struct Block {
    dense: NodeId,
    tail: Vec<NodeId>,
    d_in: u64,
    d_out: u64,
}

/// Build a random chain graph; returns the blocks for mapping.
fn random_graph(rng: &mut Rng) -> (LayerGraph, Vec<Block>, NodeId, NodeId) {
    let mut g = LayerGraph::new("prop");
    let n_layers = 1 + rng.below(3) as usize;
    let dim = |rng: &mut Rng| 8 * (1 + rng.below(8));
    let d0 = dim(rng);
    let input = g.add(LayerKind::Input { bytes: 4 * d0, marshal_insts: d0 / 4 + 40, raw_bytes: d0 });
    let mut prev = input;
    let mut d_in = d0;
    let mut blocks = Vec::new();
    for l in 0..n_layers {
        let d_out = dim(rng);
        let dense = g.chain(prev, LayerKind::Dense { rows: d_in, cols: d_out, weight_slot: l });
        prev = dense;
        let mut tail = Vec::new();
        match rng.below(3) {
            0 => {
                let relu = g.chain(prev, LayerKind::Activation { kind: ActKind::Relu, elems: d_out });
                tail.push(relu);
                prev = relu;
            }
            1 => {
                let relu = g.chain(prev, LayerKind::Activation { kind: ActKind::Relu, elems: d_out });
                let pool = g.chain(relu, LayerKind::Pool { elems: d_out, window: 2 });
                tail.push(relu);
                tail.push(pool);
                prev = pool;
            }
            _ => {
                let ew = g.chain(prev, LayerKind::Elementwise { simd_insts: d_out, fp_insts: d_out / 2 });
                tail.push(ew);
                prev = ew;
            }
        }
        blocks.push(Block { dense, tail, d_in, d_out });
        d_in = d_out;
    }
    let output = g.chain(prev, LayerKind::Output { bytes: 4 * d_in });
    (g, blocks, input, output)
}

/// Build a random valid mapping over the blocks.
fn random_mapping(rng: &mut Rng, blocks: &[Block], input: NodeId, output: NodeId) -> Mapping {
    let n_stages = 1 + rng.below(blocks.len().min(3) as u64) as usize;
    let mut tiles: Vec<TileSpec> = Vec::new();
    let mut stages: Vec<Stage> = Vec::new();
    let mut next_core = 0usize;
    for s in 0..n_stages {
        let lo = s * blocks.len() / n_stages;
        let hi = (s + 1) * blocks.len() / n_stages;
        // Occasionally column-split a stage across two cores.
        let split = rng.below(4) == 0;
        let parts = if split { 2u64 } else { 1 };
        let mut stage = Stage::on_core(next_core);
        if split {
            stage.cores = vec![next_core, next_core + 1];
            stage.split = SplitKind::Columns;
        }
        next_core += parts as usize;
        stage.input = if s == 0 { StageInput::Memory { node: input } } else { StageInput::Channel };
        stage.output = if s == n_stages - 1 {
            StageOutput::Memory { node: output }
        } else {
            StageOutput::Channel { bytes: 4 * blocks[hi - 1].d_out / parts }
        };
        if s < n_stages - 1 && rng.below(2) == 0 {
            stage.handoff = Handoff::SharedBuffer;
        }
        stage.barrier = rng.below(4) == 0;
        for b in &blocks[lo..hi] {
            let analog = rng.below(2) == 0;
            if analog {
                let mut per_replica = Vec::new();
                for _ in 0..parts {
                    let tile = tiles.len();
                    tiles.push(TileSpec {
                        rows: b.d_in as u32,
                        cols: (b.d_out / parts) as u32,
                        coupling: Coupling::Tight,
                    });
                    per_replica.push(TilePlacement {
                        tile,
                        placement: Placement {
                            row0: 0,
                            col0: 0,
                            rows: b.d_in as u32,
                            cols: (b.d_out / parts) as u32,
                        },
                    });
                }
                stage.steps.push(Step { node: b.dense, place: Place::Tile { per_replica } });
            } else {
                stage.steps.push(Step::cpu(b.dense));
            }
            for &t in &b.tail {
                stage.steps.push(Step::cpu(t));
            }
        }
        stages.push(stage);
    }
    Mapping { label: "prop/compiled".into(), tiles, min_mutexes: 0, stages }
}

/// Spec self-consistency: every index a trace op references is declared,
/// channels are driven only by their declared endpoints, ROIs balance,
/// and channel send/recv counts stay within the ping-pong capacity.
fn check_self_consistent(w: &Workload) {
    let spec = &w.spec;
    let mut sends = vec![0u64; spec.channels.len()];
    let mut recvs = vec![0u64; spec.channels.len()];
    for (core, trace) in w.traces.iter().enumerate() {
        let mut roi_depth = 0i64;
        for op in trace.iter_ops() {
            match op {
                TraceOp::CmInit { tile, .. }
                | TraceOp::CmQueue { tile, .. }
                | TraceOp::CmProcess { tile }
                | TraceOp::CmDequeue { tile, .. } => {
                    assert!(tile < spec.tiles.len(), "tile {tile} not declared");
                }
                TraceOp::MutexLock { id } | TraceOp::MutexUnlock { id } => {
                    assert!(id < spec.mutexes, "mutex {id} not declared");
                }
                TraceOp::Send { ch, .. } => {
                    assert!(ch < spec.channels.len(), "channel {ch} not declared");
                    assert_eq!(spec.channels[ch].producer, core, "send from non-producer core");
                    sends[ch] += 1;
                }
                TraceOp::Recv { ch } => {
                    assert!(ch < spec.channels.len(), "channel {ch} not declared");
                    assert_eq!(spec.channels[ch].consumer, core, "recv on non-consumer core");
                    recvs[ch] += 1;
                }
                TraceOp::RoiPush { .. } => roi_depth += 1,
                TraceOp::RoiPop => {
                    roi_depth -= 1;
                    assert!(roi_depth >= 0, "unbalanced RoiPop on core {core}");
                }
                _ => {}
            }
        }
        assert_eq!(roi_depth, 0, "unbalanced ROI markers on core {core}");
    }
    for (ch, spec_ch) in spec.channels.iter().enumerate() {
        assert!(sends[ch] > 0, "channel {ch} has no producer traffic");
        assert!(recvs[ch] > 0, "channel {ch} has no consumer traffic");
        assert!(sends[ch] >= recvs[ch], "channel {ch} under-produced");
        assert!(
            sends[ch] - recvs[ch] <= CHANNEL_CAPACITY as u64,
            "channel {ch} would overfill its ping-pong buffer"
        );
        assert_ne!(spec_ch.producer, spec_ch.consumer, "channel {ch} loops back");
    }
}

#[test]
fn compiled_random_mappings_are_self_consistent_and_run() {
    miniprop::check("compile/self-consistent-and-deadlock-free", 0xA171E5, |rng| {
        let (graph, blocks, input, output) = random_graph(rng);
        let mapping = random_mapping(rng, &blocks, input, output);
        // Straddle the looped-encoding threshold (>= 10 inferences store
        // the steady state in a Rep segment).
        let n_inf = 1 + rng.below(14) as u32;
        let w = compile(&graph, &mapping, n_inf).expect("generated mapping must be valid");
        check_self_consistent(&w);
        // Runs to completion (a deadlock is a typed RunError).
        let mut machine = Machine::new(SystemConfig::high_power(), w.spec.clone());
        let stats = machine.run(w.traces.clone()).unwrap();
        assert!(stats.roi_time_ps > 0, "machine made no progress");
    });
}

/// Random transformer-encoder shapes (attention dims, heads, cache
/// depth, FFN width) through the auto-mapper: the chosen mapping must
/// compile, pass the spec self-consistency checks, and run to
/// completion deadlock-free (a deadlock surfaces as a typed RunError).
#[test]
fn automap_transformer_choices_compile_and_run() {
    use alpine::workload::automap::{self, TopologyBudget};
    let cfg = SystemConfig::high_power();
    miniprop::check("automap/transformer-chosen-mapping-runs", 0x7_0411, |rng| {
        let heads = 1 << rng.below(3); // 1, 2, 4
        let d_model = heads * 16 * (1 + rng.below(4)); // multiples of heads, <= 256
        let seq = 8 << rng.below(3); // 8, 16, 32
        let layers = 1 + rng.below(2);
        let d_ff = 64 << rng.below(3); // 64, 128, 256
        let graph = alpine::nn::LayerGraph::transformer(d_model, heads, seq, layers, d_ff);
        let budget = TopologyBudget {
            cores: 4,
            tiles: 12,
            tile_rows: 256,
            tile_cols: 256,
            channels: 64,
        };
        let out = automap::search(&graph, &budget, &cfg, 2).expect("chain graph must search");
        assert!(!out.ranked.is_empty(), "no feasible mapping for {}", graph.name);
        let best = &out.ranked[0];
        let w = compile(&graph, &best.mapping, 2).expect("chosen mapping must compile");
        check_self_consistent(&w);
        let mut machine = Machine::new(cfg.clone(), w.spec.clone());
        let stats = machine.run(w.traces.clone()).unwrap();
        assert!(stats.roi_time_ps > 0, "machine made no progress ({})", best.desc);
    });
}

#[test]
fn paper_case_tables_are_self_consistent() {
    use alpine::nn::CnnVariant;
    use alpine::workload::{cnn, lstm, mlp};
    let cfg = SystemConfig::high_power();
    let mut all: Vec<Workload> = Vec::new();
    for case in [
        mlp::MlpCase::Digital { cores: 4 },
        mlp::MlpCase::Analog { case: 3 },
        mlp::MlpCase::Analog { case: 4 },
        mlp::MlpCase::AnalogLoose,
    ] {
        all.push(mlp::generate(case, &cfg, 2).unwrap());
    }
    all.push(lstm::generate(lstm::LstmCase::Digital { cores: 5 }, 256, &cfg, 2).unwrap());
    all.push(lstm::generate(lstm::LstmCase::Analog { case: 4 }, 512, &cfg, 2).unwrap());
    all.push(cnn::generate(cnn::CnnCase::Analog, CnnVariant::Fast, &cfg, 1).unwrap());
    for w in &all {
        check_self_consistent(w);
    }
}
