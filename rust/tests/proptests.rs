//! Property-based tests (miniprop) on simulator and coordinator
//! invariants: cache coherence of the stats, timing monotonicity,
//! energy accounting, AIMC device bounds, channel/mutex safety.

use alpine::config::{CacheGeometry, SystemConfig, SystemKind};
use alpine::coordinator::{run_workload, RunOptions};
use alpine::energy;
use alpine::isa::InstClass;
use alpine::sim::cache::{Access, Cache};
use alpine::sim::machine::{ChannelSpec, Machine, MachineSpec, TileSpec};
use alpine::sim::{Coupling, Placement};
use alpine::util::miniprop::check;
use alpine::util::rng::Rng;
use alpine::workload::mlp::{self, MlpCase};
use alpine::workload::trace::{TraceBuilder, TraceOp};

#[test]
fn cache_stats_always_consistent() {
    check("cache-stats-consistent", 0x11, |rng| {
        let geom = CacheGeometry {
            size_bytes: 1 << (9 + rng.below(4)), // 512B..4KB
            assoc: 1 << rng.below(3),            // 1..4 ways
            line_bytes: 64,
            hit_latency_cycles: 2,
        };
        let mut c = Cache::new(geom);
        let accesses = 200 + rng.below(800);
        for _ in 0..accesses {
            let addr = rng.below(1 << 14) & !63;
            let kind = if rng.below(2) == 0 { Access::Read } else { Access::Write };
            c.access(addr, kind);
        }
        assert_eq!(c.stats.accesses(), accesses);
        // Writebacks can never exceed write-allocated lines.
        assert!(c.stats.writebacks <= c.stats.write_hits + c.stats.write_misses + c.stats.read_misses);
    });
}

#[test]
fn cache_hits_bounded_by_capacity_reuse() {
    check("cache-capacity", 0x12, |rng| {
        let mut c = Cache::new(CacheGeometry {
            size_bytes: 1024,
            assoc: 2,
            line_bytes: 64,
            hit_latency_cycles: 1,
        });
        // Stream a working set strictly larger than the cache twice, in
        // order: no line can survive to the second pass (LRU + streaming).
        let lines = 2 * (1024 / 64) + rng.below(32);
        for _pass in 0..2 {
            for l in 0..lines {
                c.access(l * 64, Access::Read);
            }
        }
        assert_eq!(c.stats.read_hits, 0);
    });
}

#[test]
fn cache_bulk_stream_equals_per_line_access() {
    // `Cache::stream_run` composed with `access` on the missing line must
    // leave state and statistics bit-identical to a pure per-line
    // `access` loop, for random geometries, warm-up histories, bases
    // (aligned or not) and run lengths.
    check("cache-bulk-stream-equivalence", 0x71, |rng| {
        let geom = CacheGeometry {
            size_bytes: 1 << (9 + rng.below(4)), // 512B..4KB
            assoc: 1 << rng.below(3),            // 1..4 ways
            line_bytes: 64,
            hit_latency_cycles: 2,
        };
        let mut per_line = Cache::new(geom);
        let mut bulk = Cache::new(geom);
        // Identical random warm-up history on both.
        for _ in 0..rng.below(300) {
            let addr = rng.below(1 << 13) & !63;
            let kind = if rng.below(2) == 0 { Access::Read } else { Access::Write };
            per_line.access(addr, kind);
            bulk.access(addr, kind);
        }
        // Random sequential runs, driven per-line on one cache and via
        // the stream_run/miss composition (what MemorySystem::stream
        // does) on the other.
        for _ in 0..10 {
            let base = rng.below(1 << 13) & !7; // sometimes line-misaligned
            let lines = 1 + rng.below(40);
            let kind = if rng.below(2) == 0 { Access::Read } else { Access::Write };

            let mut ref_outcomes = Vec::new();
            for k in 0..lines {
                ref_outcomes.push(per_line.access(base + k * 64, kind));
            }

            let mut k = 0u64;
            let mut bulk_outcomes = Vec::new();
            while k < lines {
                let run = bulk.stream_run(base + k * 64, lines - k, kind);
                for _ in 0..run.hits {
                    bulk_outcomes.push((true, false));
                }
                k += run.hits;
                let Some(writeback) = run.miss_writeback else { break };
                bulk_outcomes.push((false, writeback));
                k += 1;
            }

            assert_eq!(ref_outcomes.len(), bulk_outcomes.len());
            for (r, (hit, wb)) in ref_outcomes.iter().zip(&bulk_outcomes) {
                assert_eq!(r.hit, *hit);
                assert_eq!(r.writeback, *wb);
            }
            assert_eq!(per_line.stats, bulk.stats);
        }
        // Full directory state must agree.
        for addr in (0..(1u64 << 13) + 64 * 64).step_by(64) {
            assert_eq!(per_line.probe(addr), bulk.probe(addr), "addr {addr:#x}");
        }
    });
}

#[test]
fn machine_batched_streams_equal_per_line_reference() {
    // End-to-end: the bulk MemorySystem::stream MemStream arm and the
    // per-line reference loop must produce bit-identical RunStats for
    // random mixed-stream workloads.
    check("machine-bulk-stream-equivalence", 0x72, |rng| {
        let mut b = TraceBuilder::new();
        for _ in 0..(1 + rng.below(6)) {
            b.compute(InstClass::IntAlu, 1 + rng.below(3000));
            let base = rng.below(1 << 22) & !63;
            let bytes = (1 + rng.below(128)) * 64 + rng.below(64);
            match rng.below(3) {
                0 => {
                    b.stream_read(base, bytes, 1 + rng.below(4));
                }
                1 => {
                    b.stream_write(base, bytes, 1 + rng.below(3));
                }
                _ => {
                    b.push(TraceOp::MemStream {
                        base,
                        bytes,
                        write: false,
                        insts_per_line: 2,
                        prefetchable: false,
                    });
                }
            }
        }
        let trace = b.build();
        let run = |batched: bool| {
            let mut m = Machine::new(SystemConfig::high_power(), MachineSpec::default());
            m.set_batched_streams(batched);
            m.run(vec![trace.clone()]).unwrap()
        };
        let fast = run(true);
        let reference = run(false);
        assert_eq!(fast.roi_time_ps, reference.roi_time_ps);
        assert_eq!(fast.cores[0], reference.cores[0]);
        assert_eq!(fast.l1d, reference.l1d);
        assert_eq!(fast.llc, reference.llc);
        assert_eq!(fast.dram_accesses, reference.dram_accesses);
        assert_eq!(fast.llc_bytes_read, reference.llc_bytes_read);
        assert_eq!(fast.llc_bytes_written, reference.llc_bytes_written);
    });
}

#[test]
fn nested_repeat_flattens_to_unrolled_emission() {
    // `repeat_nested` must encode — or splice — to a trace whose
    // flattened ops are bit-identical to calling the emitter for every
    // k in order, for random emitters mixing flat ops, inner `repeat`
    // loops (affine and not) and per-iteration address advances. This
    // is the PR-7 invariant the nested fast-forward rests on: the
    // looped program is a lossless encoding of the unrolled one.
    check("trace-nested-repeat-flatten", 0x74, |rng| {
        let outer = 1 + rng.below(12) as u32;
        let inner = 1 + rng.below(10) as u32;
        let affine_inner = rng.below(2) == 0;
        let affine_outer = rng.below(2) == 0;
        let stride = (1 + rng.below(8)) * 64;
        let emit = |b: &mut TraceBuilder, k: u32| {
            b.compute(InstClass::IntAlu, 10 + if affine_outer { 0 } else { (k as u64 % 3) * 7 });
            b.repeat(inner, |b, j| {
                b.stream_read(0x4000_0000 + k as u64 * 0x1_0000 + j as u64 * stride, 128, 1);
                if !affine_inner {
                    b.compute(InstClass::SimdOp, 1 + (j as u64 % 2));
                }
            });
            b.stream_write(0x9000_0000 + k as u64 * stride, 64, 1);
        };
        let mut nested = TraceBuilder::new();
        nested.repeat_nested(outer, |b, k| emit(b, k));
        let t = nested.build_trace();
        let mut unrolled = TraceBuilder::new();
        for k in 0..outer {
            emit(&mut unrolled, k);
        }
        let flat = t.flatten();
        assert_eq!(flat, unrolled.build(), "nested flatten != unrolled emission");
        assert_eq!(t.flat_len(), Some(flat.len() as u64), "flat_len disagrees with flatten");
    });
}

#[test]
fn machine_time_monotone_in_work() {
    check("machine-monotone", 0x21, |rng| {
        let insts = 1000 + rng.below(100_000);
        let run = |n: u64| {
            let mut m = Machine::new(SystemConfig::high_power(), MachineSpec::default());
            let mut b = TraceBuilder::new();
            b.compute(InstClass::IntAlu, n);
            m.run(vec![b.build()]).unwrap().roi_time_ps
        };
        assert!(run(insts + 1000) > run(insts));
    });
}

#[test]
fn machine_stats_conserve_time() {
    // active + wfm + idle cycles ≈ total ROI cycles for every core.
    check("machine-time-conservation", 0x22, |rng| {
        let mut m = Machine::new(SystemConfig::high_power(), MachineSpec::default());
        let mut b = TraceBuilder::new();
        for _ in 0..(1 + rng.below(5)) {
            b.compute(InstClass::IntAlu, 100 + rng.below(10_000));
            b.stream_read(0x1000_0000 + rng.below(1 << 20) * 64, (1 + rng.below(64)) * 64, 2);
        }
        let rs = m.run(vec![b.build()]).unwrap();
        let cfg = SystemConfig::high_power();
        let total = rs.roi_time_ps / cfg.cycle_ps();
        let accounted = rs.cores[0].total_cycles();
        let drift = (total as f64 - accounted as f64).abs() / total.max(1) as f64;
        assert!(drift < 0.02, "total {total} vs accounted {accounted}");
    });
}

#[test]
fn energy_positive_and_monotone_in_time() {
    check("energy-monotone", 0x23, |rng| {
        let cfg = SystemConfig::for_kind(if rng.below(2) == 0 {
            SystemKind::HighPower
        } else {
            SystemKind::LowPower
        });
        let mut m = Machine::new(cfg.clone(), MachineSpec::default());
        let mut b = TraceBuilder::new();
        b.compute(InstClass::IntAlu, 1000 + rng.below(50_000));
        let rs = m.run(vec![b.build()]).unwrap();
        let e = energy::compute(&cfg, &rs);
        assert!(e.total_j() > 0.0);
        assert!(e.core_active_j > 0.0);
        // Static terms scale with ROI duration.
        assert!(e.mem_ctrl_io_j > 0.0);
    });
}

#[test]
fn tile_device_ports_never_regress() {
    // The tile pipelines across its two ports (I/O register file vs the
    // crossbar), so global completion times may interleave — but each
    // port serializes, completions never precede issue, and a dequeue
    // never completes before the MVM whose result it retrieves.
    check("tile-port-monotone", 0x31, |rng| {
        let cfg = SystemConfig::high_power();
        let mut tile = alpine::sim::AimcTile::new(&cfg.aimc, 512, 512, Coupling::Tight);
        let mut now = 0u64;
        let mut last_io_done = 0u64;
        let mut last_xbar_done = 0u64;
        let mut pending_process_done: Vec<u64> = Vec::new();
        for _ in 0..50 {
            now += rng.below(200_000);
            match rng.below(3) {
                0 => {
                    let done = tile.queue(now, 1 + rng.below(512)).unwrap();
                    assert!(done >= now);
                    assert!(done >= last_io_done, "I/O port must serialize");
                    last_io_done = done;
                }
                1 => {
                    let done = tile.process(now);
                    assert!(done >= now);
                    assert!(done >= last_xbar_done, "crossbar must serialize");
                    last_xbar_done = done;
                    pending_process_done.push(done);
                }
                _ => {
                    let done = tile.dequeue(now, 1 + rng.below(512)).unwrap();
                    assert!(done >= now);
                    assert!(done >= last_io_done, "I/O port must serialize");
                    if !pending_process_done.is_empty() {
                        let dep = pending_process_done.remove(0);
                        assert!(done >= dep, "dequeue before its MVM finished");
                    }
                    last_io_done = done;
                }
            }
        }
    });
}

#[test]
fn placement_overlap_detection_symmetric() {
    check("placement-overlap", 0x32, |rng| {
        let mk = |rng: &mut Rng| Placement {
            row0: rng.below(100) as u32,
            col0: rng.below(100) as u32,
            rows: 1 + rng.below(100) as u32,
            cols: 1 + rng.below(100) as u32,
        };
        let a = mk(rng);
        let b = mk(rng);
        assert_eq!(a.overlaps(&b), b.overlaps(&a));
        assert!(a.overlaps(&a));
    });
}

#[test]
fn pipeline_never_loses_messages() {
    check("channel-conservation", 0x41, |rng| {
        let n_msgs = 1 + rng.below(20) as u32;
        let spec = MachineSpec {
            channels: vec![ChannelSpec { producer: 0, consumer: 1, capacity: 2 }],
            ..Default::default()
        };
        let mut m = Machine::new(SystemConfig::high_power(), spec);
        let mut p = TraceBuilder::new();
        let mut c = TraceBuilder::new();
        for k in 0..n_msgs {
            p.compute(InstClass::IntAlu, 1 + rng.below(5000));
            p.push(TraceOp::Send { ch: 0, bytes: 64, addr: 0x6000 + (k as u64 % 2) * 4096 });
            c.compute(InstClass::IntAlu, 1 + rng.below(5000));
            c.push(TraceOp::Recv { ch: 0 });
        }
        let rs = m.run(vec![p.build(), c.build()]).unwrap();
        assert!(rs.roi_time_ps > 0);
        // If a message were lost the consumer would deadlock (a RunError).
    });
}

#[test]
fn mutex_workloads_complete_without_deadlock() {
    check("mutex-completion", 0x42, |rng| {
        let cores = 2 + rng.below(4) as usize;
        let spec = MachineSpec { mutexes: 1, ..Default::default() };
        let mut m = Machine::new(SystemConfig::high_power(), spec);
        let traces: Vec<_> = (0..cores)
            .map(|_| {
                let mut b = TraceBuilder::new();
                for _ in 0..(1 + rng.below(5)) {
                    b.push(TraceOp::MutexLock { id: 0 });
                    b.compute(InstClass::IntAlu, 1 + rng.below(2000));
                    b.push(TraceOp::MutexUnlock { id: 0 });
                }
                b.build()
            })
            .collect();
        let rs = m.run(traces).unwrap();
        assert!(rs.roi_time_ps > 0);
    });
}

#[test]
fn workload_generation_scales_linearly_with_inferences() {
    check("workload-linear", 0x51, |rng| {
        let n = 1 + rng.below(6) as u32;
        let cfg = SystemConfig::high_power();
        let w1 = mlp::generate(MlpCase::Analog { case: 1 }, &cfg, n).unwrap();
        let w2 = mlp::generate(MlpCase::Analog { case: 1 }, &cfg, 2 * n).unwrap();
        // Ops scale ~linearly (init ops are constant).
        let per1 = (w1.total_ops() - 2) as f64 / n as f64;
        let per2 = (w2.total_ops() - 2) as f64 / (2 * n) as f64;
        assert!((per1 - per2).abs() < 1e-9);
    });
}

#[test]
fn more_inferences_take_proportionally_longer() {
    check("inference-scaling", 0x52, |rng| {
        let n = 2 + rng.below(4) as u32;
        let cfg = SystemConfig::high_power();
        let ro = RunOptions::default();
        let r1 = run_workload(SystemKind::HighPower, mlp::generate(MlpCase::Analog { case: 1 }, &cfg, n).unwrap(), &ro).unwrap();
        let r2 = run_workload(SystemKind::HighPower, mlp::generate(MlpCase::Analog { case: 1 }, &cfg, 2 * n).unwrap(), &ro).unwrap();
        let ratio = r2.time_s / r1.time_s;
        assert!(
            (1.6..2.4).contains(&ratio),
            "2x inferences should be ~2x time (cold-start amortization aside): {ratio}"
        );
    });
}

#[test]
fn loose_tile_spec_roundtrip() {
    check("tilespec-coupling", 0x61, |rng| {
        let coupling = if rng.below(2) == 0 { Coupling::Tight } else { Coupling::Loose };
        let spec = MachineSpec {
            tiles: vec![TileSpec { rows: 64, cols: 64, coupling }],
            ..Default::default()
        };
        let m = Machine::new(SystemConfig::low_power(), spec);
        assert_eq!(m.tiles()[0].coupling, coupling);
    });
}
