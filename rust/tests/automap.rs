//! Automap acceptance tests: the searched mapping space, the analytic
//! cost models vs the simulator, determinism under `--jobs N`, the
//! ISSUE-3 acceptance criterion (best transformer mapping beats the
//! naive all-digital single-core mapping on simulated cycles), and the
//! ISSUE-5 equivalence gates: the compositional cost engine ranks
//! candidates identically to the full-compile oracle, and the pruned
//! branch-and-bound walk returns exactly the exhaustive walk's outcome.
//! PR 7 adds the compile-cache gate: the compiled oracle returns
//! bit-identical `SearchOutcome`s with the cross-candidate fragment
//! cache on and off, at any `--jobs N`.

use alpine::config::{SystemConfig, SystemKind};
use alpine::coordinator::automap::{run_search, AutomapOptions};
use alpine::nn::LayerGraph;
use alpine::util::miniprop;
use alpine::workload::automap::{self, CostModel, SearchOptions, TopologyBudget};
use alpine::workload::mlp::{self, MlpCase};
use alpine::workload::transformer::TransformerShape;

fn transformer_graph() -> LayerGraph {
    TransformerShape::new(128, 4, 32, 1, 256).unwrap().graph()
}

fn budget() -> TopologyBudget {
    TopologyBudget { cores: 4, tiles: 12, tile_rows: 256, tile_cols: 256, channels: 32 }
}

/// ISSUE-3 acceptance: `automap` on a transformer-encoder `LayerGraph`
/// returns a Pareto front whose best mapping runs end-to-end
/// deadlock-free through the simulator (a deadlock is a `RunError`) and beats
/// the naive all-digital single-core mapping on simulated cycles.
#[test]
fn automap_transformer_beats_naive_digital() {
    let graph = transformer_graph();
    let opts = AutomapOptions { top_k: 6, n_inf: 3, jobs: 2, ..Default::default() };
    let rep = run_search(&graph, &budget(), SystemKind::HighPower, opts).unwrap();

    assert!(rep.feasible > 4, "search space collapsed: {} feasible", rep.feasible);
    assert!(rep.front().count() >= 1, "empty Pareto front");
    let best = rep.best_row();
    let base = rep.baseline_row();
    assert!(
        best.result.time_s < base.result.time_s,
        "best {} ({}s) does not beat the digital baseline ({}s)",
        best.desc,
        best.result.time_s,
        base.result.time_s
    );
    // The winner must actually use the AIMC fabric.
    assert!(best.desc.contains('A'), "best mapping is not analog: {}", best.desc);
    // The fastest row is by definition non-dominated.
    assert!(best.pareto);
}

/// ISSUE-3 satellite: the search must be deterministic under `--jobs N`
/// — same rows, bit-identical metrics, same front, at any worker count.
#[test]
fn automap_parallel_identical_to_serial() {
    let graph = transformer_graph();
    let serial = run_search(
        &graph,
        &budget(),
        SystemKind::HighPower,
        AutomapOptions { top_k: 5, n_inf: 2, jobs: 1, ..Default::default() },
    )
    .unwrap();
    let parallel = run_search(
        &graph,
        &budget(),
        SystemKind::HighPower,
        AutomapOptions { top_k: 5, n_inf: 2, jobs: 4, ..Default::default() },
    )
    .unwrap();

    assert_eq!(serial.enumerated, parallel.enumerated);
    assert_eq!(serial.pruned, parallel.pruned);
    assert_eq!(serial.feasible, parallel.feasible);
    assert_eq!(serial.rows.len(), parallel.rows.len());
    assert_eq!(serial.best, parallel.best);
    assert_eq!(serial.baseline, parallel.baseline);
    for (a, b) in serial.rows.iter().zip(&parallel.rows) {
        assert_eq!(a.desc, b.desc);
        assert_eq!(a.pareto, b.pareto);
        assert_eq!(a.baseline, b.baseline);
        assert_eq!(a.est_cycles.to_bits(), b.est_cycles.to_bits(), "{}", a.desc);
        assert_eq!(a.result.time_s.to_bits(), b.result.time_s.to_bits(), "{}", a.desc);
        assert_eq!(
            a.result.energy.total_j().to_bits(),
            b.result.energy.total_j().to_bits(),
            "{}",
            a.desc
        );
        assert_eq!(a.result.total_insts, b.result.total_insts);
        assert_eq!(a.result.dram_accesses, b.result.dram_accesses);
        assert_eq!(a.result.aimc_processes, b.result.aimc_processes);
    }
}

/// ISSUE-3 satellite: the analytic cost model stays within a fixed
/// tolerance of simulated cycles for the paper's MLP cases. The model
/// prunes a search space, so a bounded ratio — not exactness — is the
/// contract. Single-stage mappings are pinned to [0.4, 2.5]; the
/// pipelined case gets [0.3, 2.8] (the steady-state max-stage model
/// ignores consumer wake latencies and ack round trips), and the
/// digital-vs-analog ordering must match the simulator.
#[test]
fn cost_model_tracks_simulated_cycles() {
    let cfg = SystemConfig::high_power();
    let mut sim_cycles = Vec::new();
    let mut est_cycles = Vec::new();
    for (case, lo, hi) in [
        (MlpCase::Digital { cores: 1 }, 0.4, 2.5),
        (MlpCase::Analog { case: 1 }, 0.4, 2.5),
        (MlpCase::Analog { case: 3 }, 0.3, 2.8),
    ] {
        let (graph, mapping) = mlp::case_table(case).unwrap();
        let est = automap::estimate(&graph, &mapping, &cfg).unwrap();
        let w = mlp::generate(case, &cfg, 10).unwrap();
        let r = alpine::coordinator::run_workload(
            SystemKind::HighPower,
            w,
            &alpine::coordinator::RunOptions::default(),
        )
        .unwrap();
        let sim = r.time_per_inference_s * cfg.freq_hz;
        let ratio = est.cycles_per_inf / sim;
        assert!(
            (lo..=hi).contains(&ratio),
            "{}: estimate {:.0} vs simulated {:.0} cycles/inf (ratio {:.2}, bound [{lo}, {hi}])",
            r.label,
            est.cycles_per_inf,
            sim,
            ratio
        );
        sim_cycles.push(sim);
        est_cycles.push(est.cycles_per_inf);
    }
    // Ordering agreement: both rank ANA-case1 well ahead of DIG-1core.
    assert!(sim_cycles[1] < sim_cycles[0]);
    assert!(est_cycles[1] < est_cycles[0]);
}

/// The MLP space (the paper's own workload) also searches end-to-end:
/// analog candidates appear and the best simulated mapping beats the
/// digital baseline.
#[test]
fn automap_mlp_search_end_to_end() {
    let graph = LayerGraph::mlp(&[256, 256, 64]);
    let rep = run_search(
        &graph,
        &budget(),
        SystemKind::HighPower,
        AutomapOptions { top_k: 6, n_inf: 3, jobs: 2, ..Default::default() },
    )
    .unwrap();
    assert!(rep.speedup_vs_baseline() > 1.0, "speedup {:.2}", rep.speedup_vs_baseline());
    assert!(rep.rows.iter().any(|r| r.desc.contains('A')));
}

fn descs(o: &automap::SearchOutcome) -> Vec<String> {
    o.ranked.iter().map(|c| c.desc.clone()).collect()
}

fn front_descs(o: &automap::SearchOutcome) -> Vec<String> {
    o.front.iter().map(|c| c.desc.clone()).collect()
}

/// The two engines sum the same op multiset in different f64 orders, so
/// exact math ties may resolve a round-off apart and legally swap
/// positions (or hop across the top-k boundary / a front-dominance
/// test). Equivalence therefore means: same chosen mapping, per-desc
/// costs within round-off, and any set/order difference confined to
/// sub-round-off near-ties — a real modeling divergence blows far past
/// `REL_EPS` and still fails loudly.
const REL_EPS: f64 = 1e-9;
/// `top_k` both gate searches run with (the cycles-cut boundary of the
/// near-tie fallback in `assert_ranked_equivalent`).
const GATE_TOP_K: usize = 6;

fn ranked_of(o: &automap::SearchOutcome) -> Vec<(String, f64, f64)> {
    o.ranked.iter().map(|c| (c.desc.clone(), c.est.cycles_per_inf, c.est.energy_per_inf_j)).collect()
}

fn front_of(o: &automap::SearchOutcome) -> Vec<(String, f64, f64)> {
    o.front.iter().map(|c| (c.desc.clone(), c.est.cycles_per_inf, c.est.energy_per_inf_j)).collect()
}

fn assert_ranked_equivalent(name: &str, a: &automap::SearchOutcome, b: &automap::SearchOutcome) {
    assert_eq!(
        a.ranked[0].desc, b.ranked[0].desc,
        "{name}: chosen mapping differs ({} vs {})",
        a.ranked[0].desc, b.ranked[0].desc
    );
    let (ra, rb) = (ranked_of(a), ranked_of(b));
    for (xs, ys, side) in [(&ra, &rb, "first"), (&rb, &ra, "second")] {
        for (desc, cyc, en) in xs {
            match ys.iter().find(|(d, _, _)| d == desc) {
                Some((_, c2, e2)) => {
                    assert!(
                        (cyc - c2).abs() <= REL_EPS * cyc && (en - e2).abs() <= REL_EPS * en,
                        "{name} {desc}: cost drift beyond round-off ({cyc} vs {c2}, {en} vs {e2})"
                    );
                }
                None => {
                    // Only admissible when it straddles a selection
                    // boundary by round-off: its cycles sit at the other
                    // side's top-k-by-cycles cut, or its energy at the
                    // worst kept energy (an upper bound on the
                    // energy-extras cut), within eps. A genuinely
                    // better-or-worse candidate missing from one side
                    // still fails.
                    let mut cycs: Vec<f64> = ys.iter().map(|(_, c2, _)| *c2).collect();
                    cycs.sort_by(f64::total_cmp);
                    let cyc_cut = cycs.get(GATE_TOP_K - 1).copied().unwrap_or(f64::INFINITY);
                    let worst_e = ys.iter().map(|(_, _, e2)| *e2).fold(0f64, f64::max);
                    let near_cyc = (cyc - cyc_cut).abs() <= REL_EPS * cyc_cut;
                    let near_en = (en - worst_e).abs() <= REL_EPS * worst_e;
                    assert!(
                        near_cyc || near_en,
                        "{name}: candidate {desc} ranked only on the {side} side and is no near-tie"
                    );
                }
            }
        }
    }
}

fn assert_front_equivalent(name: &str, a: &automap::SearchOutcome, b: &automap::SearchOutcome) {
    let (fa, fb) = (front_of(a), front_of(b));
    for (xs, ys, side) in [(&fa, &fb, "first"), (&fb, &fa, "second")] {
        for (desc, cyc, en) in xs {
            if ys.iter().any(|(d, _, _)| d == desc) {
                continue;
            }
            // A front point missing from the other side must be within
            // round-off of being dominated there (an ulp-scale dominance
            // flip, not a modeling divergence).
            let nearly_dominated = ys
                .iter()
                .any(|(_, c2, e2)| *c2 <= cyc * (1.0 + REL_EPS) && *e2 <= en * (1.0 + REL_EPS));
            assert!(
                nearly_dominated,
                "{name}: front point {desc} only on the {side} side and is no near-tie"
            );
        }
    }
}

/// ISSUE-5 gate: on every pinned MLP + transformer case, the
/// compositional engine must (a) agree with the compiled oracle on
/// which candidates are feasible, (b) return the same chosen mapping,
/// ranked candidates, and estimated Pareto front (modulo sub-round-off
/// near-ties — see `REL_EPS`), and (c) estimate every candidate within
/// f64 round-off of the oracle.
#[test]
fn compositional_matches_compiled_oracle_on_pinned_cases() {
    let cfg = SystemConfig::high_power();
    let cases: Vec<(&str, LayerGraph)> = vec![
        ("mlp-256-128-64", LayerGraph::mlp(&[256, 128, 64])),
        ("mlp-256-256-64", LayerGraph::mlp(&[256, 256, 64])),
        ("mlp-784-512-256-128-10", LayerGraph::mlp(&[784, 512, 256, 128, 10])),
        ("mlp-wide-128-512", LayerGraph::mlp(&[128, 512])),
        ("transformer-l1", transformer_graph()),
        ("transformer-l2", TransformerShape::new(128, 4, 32, 2, 256).unwrap().graph()),
    ];
    for (name, graph) in cases {
        // Exhaustive on both engines (cap = MAX disables pruning) so
        // feasibility can be compared 1:1, depth/replication clamped to
        // keep the compiled walk fast.
        let exhaustive = |model: CostModel| SearchOptions {
            top_k: GATE_TOP_K,
            model,
            cap: Some(usize::MAX),
            max_depth: 4,
            max_replica: 4,
            jobs: 1,
            // The oracle leg runs with the PR-7 compile cache on: cached
            // scoring is bit-identical to uncached by construction
            // (gated under proptest below), so the ISSUE-5 comparison
            // doubles as a cache-correctness check.
            compile_cache: true,
        };
        let oracle =
            automap::search_opts(&graph, &budget(), &cfg, &exhaustive(CostModel::Compiled)).unwrap();
        let composed =
            automap::search_opts(&graph, &budget(), &cfg, &exhaustive(CostModel::Compositional))
                .unwrap();
        assert_eq!(oracle.enumerated, composed.enumerated, "{name}: enumerated drift");
        assert_eq!(oracle.feasible, composed.feasible, "{name}: feasibility drift");
        assert_ranked_equivalent(name, &oracle, &composed);
        assert_front_equivalent(name, &oracle, &composed);
        // The pruned branch-and-bound walk (the production default)
        // returns the same chosen mapping and front as the oracle.
        let bnb = automap::search_opts(
            &graph,
            &budget(),
            &cfg,
            &SearchOptions {
                top_k: GATE_TOP_K,
                max_depth: 4,
                max_replica: 4,
                jobs: 2,
                ..Default::default()
            },
        )
        .unwrap();
        assert_ranked_equivalent(name, &oracle, &bnb);
        assert_front_equivalent(name, &oracle, &bnb);
        // Within one engine there is no fp-order ambiguity: pruned ==
        // exhaustive compositional exactly.
        assert_eq!(descs(&composed), descs(&bnb), "{name}: pruned ranking drift");
        assert_eq!(front_descs(&composed), front_descs(&bnb), "{name}: pruned front drift");
    }
}

/// ISSUE-5 gate (proptest): over random MLP chains, budgets, and
/// worker counts, the branch-and-bound walk returns bit-identical
/// outcomes to the exhaustive compositional walk — same ranked descs,
/// same estimated Pareto front, same estimates to the bit — and the
/// parallel walk is bit-identical to serial.
#[test]
fn pruned_search_equals_exhaustive_under_proptest() {
    let cfg = SystemConfig::high_power();
    miniprop::check("automap/bnb-equals-exhaustive", 0x5_0711, |rng| {
        let n_layers = 1 + rng.below(3) as usize;
        let mut dims: Vec<u64> = vec![8 * (1 + rng.below(32))];
        for _ in 0..n_layers {
            dims.push(8 * (1 + rng.below(32)));
        }
        let graph = LayerGraph::mlp(&dims);
        let budget = TopologyBudget {
            cores: 1 + rng.below(6) as usize,
            tiles: rng.below(8) as usize,
            tile_rows: 64u32 << rng.below(3),
            tile_cols: 64u32 << rng.below(3),
            channels: rng.below(48) as usize,
        };
        let top_k = 1 + rng.below(6) as usize;
        let jobs = [1, 3, 8][rng.below(3) as usize];
        let base = SearchOptions { top_k, ..Default::default() };
        let exhaustive = automap::search_opts(
            &graph,
            &budget,
            &cfg,
            &SearchOptions { cap: Some(usize::MAX), ..base.clone() },
        )
        .unwrap();
        let pruned = automap::search_opts(&graph, &budget, &cfg, &base).unwrap();
        let parallel = automap::search_opts(
            &graph,
            &budget,
            &cfg,
            &SearchOptions { jobs, ..base.clone() },
        )
        .unwrap();
        assert!(!exhaustive.truncated);
        assert_eq!(exhaustive.enumerated, pruned.enumerated, "space size drift");
        assert_eq!(descs(&exhaustive), descs(&pruned), "pruned ranking drift");
        assert_eq!(front_descs(&exhaustive), front_descs(&pruned), "pruned front drift");
        for (a, b) in exhaustive.ranked.iter().zip(&pruned.ranked) {
            assert_eq!(a.est.cycles_per_inf.to_bits(), b.est.cycles_per_inf.to_bits(), "{}", a.desc);
            assert_eq!(a.est.energy_per_inf_j.to_bits(), b.est.energy_per_inf_j.to_bits(), "{}", a.desc);
        }
        // Parallel == serial, to the bit, including the counters.
        assert_eq!(pruned.enumerated, parallel.enumerated);
        assert_eq!(pruned.pruned, parallel.pruned);
        assert_eq!(pruned.feasible, parallel.feasible);
        assert_eq!(descs(&pruned), descs(&parallel));
        assert_eq!(front_descs(&pruned), front_descs(&parallel));
        for (a, b) in pruned.ranked.iter().zip(&parallel.ranked) {
            assert_eq!(a.est.cycles_per_inf.to_bits(), b.est.cycles_per_inf.to_bits(), "{}", a.desc);
        }

        // ISSUE-7 gate: the compiled-oracle compile cache is score
        // invisible — cache-on (shared across workers, at a random
        // `jobs`) and cache-off return bit-identical outcomes. Depth
        // and replication are clamped to keep the per-candidate
        // compile oracle affordable under proptest.
        let compiled = |cc: bool, jobs: usize| SearchOptions {
            top_k,
            model: CostModel::Compiled,
            cap: Some(usize::MAX),
            max_depth: 3,
            max_replica: 2,
            jobs,
            compile_cache: cc,
        };
        let cached = automap::search_opts(&graph, &budget, &cfg, &compiled(true, jobs)).unwrap();
        let uncached = automap::search_opts(&graph, &budget, &cfg, &compiled(false, 1)).unwrap();
        assert_eq!(cached.enumerated, uncached.enumerated);
        assert_eq!(cached.pruned, uncached.pruned);
        assert_eq!(cached.feasible, uncached.feasible);
        assert_eq!(descs(&cached), descs(&uncached), "compile-cache ranking drift");
        assert_eq!(front_descs(&cached), front_descs(&uncached), "compile-cache front drift");
        for (a, b) in cached.ranked.iter().zip(&uncached.ranked) {
            assert_eq!(a.est.cycles_per_inf.to_bits(), b.est.cycles_per_inf.to_bits(), "{}", a.desc);
            assert_eq!(
                a.est.energy_per_inf_j.to_bits(),
                b.est.energy_per_inf_j.to_bits(),
                "{}",
                a.desc
            );
        }
        assert!(cached.cache.is_some(), "cache-enabled compiled search must report stats");
        assert!(uncached.cache.is_none(), "cache-disabled search must not report stats");
    });
}
