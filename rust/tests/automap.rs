//! Automap acceptance tests: the searched mapping space, the analytic
//! cost model vs the simulator, determinism under `--jobs N`, and the
//! ISSUE-3 acceptance criterion (best transformer mapping beats the
//! naive all-digital single-core mapping on simulated cycles).

use alpine::config::{SystemConfig, SystemKind};
use alpine::coordinator::automap::{run_search, AutomapOptions};
use alpine::nn::LayerGraph;
use alpine::workload::automap::{self, TopologyBudget};
use alpine::workload::mlp::{self, MlpCase};
use alpine::workload::transformer::TransformerShape;

fn transformer_graph() -> LayerGraph {
    TransformerShape::new(128, 4, 32, 1, 256).unwrap().graph()
}

fn budget() -> TopologyBudget {
    TopologyBudget { cores: 4, tiles: 12, tile_rows: 256, tile_cols: 256, channels: 32 }
}

/// ISSUE-3 acceptance: `automap` on a transformer-encoder `LayerGraph`
/// returns a Pareto front whose best mapping runs end-to-end
/// deadlock-free through the simulator (a deadlock panics) and beats
/// the naive all-digital single-core mapping on simulated cycles.
#[test]
fn automap_transformer_beats_naive_digital() {
    let graph = transformer_graph();
    let opts = AutomapOptions { top_k: 6, n_inf: 3, jobs: 2 };
    let rep = run_search(&graph, &budget(), SystemKind::HighPower, opts).unwrap();

    assert!(rep.feasible > 4, "search space collapsed: {} feasible", rep.feasible);
    assert!(rep.front().count() >= 1, "empty Pareto front");
    let best = rep.best_row();
    let base = rep.baseline_row();
    assert!(
        best.result.time_s < base.result.time_s,
        "best {} ({}s) does not beat the digital baseline ({}s)",
        best.desc,
        best.result.time_s,
        base.result.time_s
    );
    // The winner must actually use the AIMC fabric.
    assert!(best.desc.contains('A'), "best mapping is not analog: {}", best.desc);
    // The fastest row is by definition non-dominated.
    assert!(best.pareto);
}

/// ISSUE-3 satellite: the search must be deterministic under `--jobs N`
/// — same rows, bit-identical metrics, same front, at any worker count.
#[test]
fn automap_parallel_identical_to_serial() {
    let graph = transformer_graph();
    let serial = run_search(
        &graph,
        &budget(),
        SystemKind::HighPower,
        AutomapOptions { top_k: 5, n_inf: 2, jobs: 1 },
    )
    .unwrap();
    let parallel = run_search(
        &graph,
        &budget(),
        SystemKind::HighPower,
        AutomapOptions { top_k: 5, n_inf: 2, jobs: 4 },
    )
    .unwrap();

    assert_eq!(serial.enumerated, parallel.enumerated);
    assert_eq!(serial.feasible, parallel.feasible);
    assert_eq!(serial.rows.len(), parallel.rows.len());
    assert_eq!(serial.best, parallel.best);
    assert_eq!(serial.baseline, parallel.baseline);
    for (a, b) in serial.rows.iter().zip(&parallel.rows) {
        assert_eq!(a.desc, b.desc);
        assert_eq!(a.pareto, b.pareto);
        assert_eq!(a.baseline, b.baseline);
        assert_eq!(a.est_cycles.to_bits(), b.est_cycles.to_bits(), "{}", a.desc);
        assert_eq!(a.result.time_s.to_bits(), b.result.time_s.to_bits(), "{}", a.desc);
        assert_eq!(
            a.result.energy.total_j().to_bits(),
            b.result.energy.total_j().to_bits(),
            "{}",
            a.desc
        );
        assert_eq!(a.result.total_insts, b.result.total_insts);
        assert_eq!(a.result.dram_accesses, b.result.dram_accesses);
        assert_eq!(a.result.aimc_processes, b.result.aimc_processes);
    }
}

/// ISSUE-3 satellite: the analytic cost model stays within a fixed
/// tolerance of simulated cycles for the paper's MLP cases. The model
/// prunes a search space, so a bounded ratio — not exactness — is the
/// contract. Single-stage mappings are pinned to [0.4, 2.5]; the
/// pipelined case gets [0.3, 2.8] (the steady-state max-stage model
/// ignores consumer wake latencies and ack round trips), and the
/// digital-vs-analog ordering must match the simulator.
#[test]
fn cost_model_tracks_simulated_cycles() {
    let cfg = SystemConfig::high_power();
    let mut sim_cycles = Vec::new();
    let mut est_cycles = Vec::new();
    for (case, lo, hi) in [
        (MlpCase::Digital { cores: 1 }, 0.4, 2.5),
        (MlpCase::Analog { case: 1 }, 0.4, 2.5),
        (MlpCase::Analog { case: 3 }, 0.3, 2.8),
    ] {
        let (graph, mapping) = mlp::case_table(case).unwrap();
        let est = automap::estimate(&graph, &mapping, &cfg).unwrap();
        let w = mlp::generate(case, &cfg, 10).unwrap();
        let r = alpine::coordinator::run_workload(SystemKind::HighPower, w);
        let sim = r.time_per_inference_s * cfg.freq_hz;
        let ratio = est.cycles_per_inf / sim;
        assert!(
            (lo..=hi).contains(&ratio),
            "{}: estimate {:.0} vs simulated {:.0} cycles/inf (ratio {:.2}, bound [{lo}, {hi}])",
            r.label,
            est.cycles_per_inf,
            sim,
            ratio
        );
        sim_cycles.push(sim);
        est_cycles.push(est.cycles_per_inf);
    }
    // Ordering agreement: both rank ANA-case1 well ahead of DIG-1core.
    assert!(sim_cycles[1] < sim_cycles[0]);
    assert!(est_cycles[1] < est_cycles[0]);
}

/// The MLP space (the paper's own workload) also searches end-to-end:
/// analog candidates appear and the best simulated mapping beats the
/// digital baseline.
#[test]
fn automap_mlp_search_end_to_end() {
    let graph = LayerGraph::mlp(&[256, 256, 64]);
    let rep = run_search(
        &graph,
        &budget(),
        SystemKind::HighPower,
        AutomapOptions { top_k: 6, n_inf: 3, jobs: 2 },
    )
    .unwrap();
    assert!(rep.speedup_vs_baseline() > 1.0, "speedup {:.2}", rep.speedup_vs_baseline());
    assert!(rep.rows.iter().any(|r| r.desc.contains('A')));
}
