//! The IR-equivalence gate: for every paper case, the `(LayerGraph,
//! Mapping)` pair compiled by `workload::compile` must be **bit-
//! identical** to the legacy hand-written generator it replaced — same
//! per-core `TraceOp` streams, same `MachineSpec`, and therefore the
//! same `RunStats` down to the last bit. CI runs this file as the
//! `ir-equivalence` job; once the compiler has soaked, `workload::legacy`
//! and this file can be deleted together.

use alpine::config::{SystemConfig, SystemKind};
use alpine::coordinator::{run_workload, RunOptions};
use alpine::nn::CnnVariant;
use alpine::stats::RoiKind;
use alpine::workload::cnn::{self, CnnCase};
use alpine::workload::legacy;
use alpine::workload::lstm::{self, LstmCase};
use alpine::workload::mlp::{self, MlpCase};
use alpine::workload::Workload;

const MLP_CASES: [MlpCase; 8] = [
    MlpCase::Digital { cores: 1 },
    MlpCase::Digital { cores: 2 },
    MlpCase::Digital { cores: 4 },
    MlpCase::Analog { case: 1 },
    MlpCase::Analog { case: 2 },
    MlpCase::Analog { case: 3 },
    MlpCase::Analog { case: 4 },
    MlpCase::AnalogLoose,
];

const LSTM_CASES: [LstmCase; 7] = [
    LstmCase::Digital { cores: 1 },
    LstmCase::Digital { cores: 2 },
    LstmCase::Digital { cores: 5 },
    LstmCase::Analog { case: 1 },
    LstmCase::Analog { case: 2 },
    LstmCase::Analog { case: 3 },
    LstmCase::Analog { case: 4 },
];

fn hp() -> SystemConfig {
    SystemConfig::high_power()
}

/// Traces + spec, op by op on the *flattened* form (the compiler stores
/// looped `Rep` programs; the oracle generators emit flat streams — the
/// per-op compare keeps failure output small even on multi-megaop CNN
/// traces).
fn assert_workloads_identical(oracle: &Workload, compiled: &Workload) {
    assert_eq!(compiled.label, oracle.label, "label");
    assert_eq!(compiled.inferences, oracle.inferences, "{}", oracle.label);
    assert_eq!(compiled.spec, oracle.spec, "{}: MachineSpec differs", oracle.label);
    assert_eq!(compiled.traces.len(), oracle.traces.len(), "{}: core count", oracle.label);
    for (core, (a, b)) in oracle.traces.iter().zip(&compiled.traces).enumerate() {
        assert_eq!(a.op_count(), b.op_count(), "{} core {core}: op count", oracle.label);
        for (k, (x, y)) in a.iter_ops().zip(b.iter_ops()).enumerate() {
            assert_eq!(x, y, "{} core {core} op {k}", oracle.label);
        }
    }
}

/// Full-run statistics, bit for bit.
fn assert_stats_identical(kind: SystemKind, oracle: Workload, compiled: Workload) {
    let a = run_workload(kind, oracle, &RunOptions::default()).unwrap();
    let b = run_workload(kind, compiled, &RunOptions::default()).unwrap();
    assert_eq!(a.label, b.label);
    assert_eq!(a.time_s.to_bits(), b.time_s.to_bits(), "{}", a.label);
    assert_eq!(a.time_per_inference_s.to_bits(), b.time_per_inference_s.to_bits(), "{}", a.label);
    assert_eq!(a.llc_mpki.to_bits(), b.llc_mpki.to_bits(), "{}", a.label);
    assert_eq!(a.energy.total_j().to_bits(), b.energy.total_j().to_bits(), "{}", a.label);
    assert_eq!(a.total_insts, b.total_insts, "{}", a.label);
    assert_eq!(a.dram_accesses, b.dram_accesses, "{}", a.label);
    assert_eq!(a.aimc_processes, b.aimc_processes, "{}", a.label);
    assert_eq!(a.per_core_ipc.len(), b.per_core_ipc.len());
    for (x, y) in a.per_core_ipc.iter().zip(&b.per_core_ipc) {
        assert_eq!(x.to_bits(), y.to_bits(), "{}", a.label);
    }
    for (x, y) in a.per_core_idle.iter().zip(&b.per_core_idle) {
        assert_eq!(x.to_bits(), y.to_bits(), "{}", a.label);
    }
    for (x, y) in a.per_core_wfm.iter().zip(&b.per_core_wfm) {
        assert_eq!(x.to_bits(), y.to_bits(), "{}", a.label);
    }
    for kind in RoiKind::ALL {
        assert_eq!(a.roi.get(kind), b.roi.get(kind), "{} roi {kind:?}", a.label);
    }
}

/// The fluent `GraphBuilder` must be a pure re-spelling of the chain
/// constructors: the same linear chain assembled node by node is
/// **equal** to `LayerGraph::mlp`'s output and compiles bit-identically
/// under the same mapping — so DAG support cannot drift the linear-chain
/// path even at the IR-construction layer.
#[test]
fn graphbuilder_chain_bit_identical_to_mlp_constructor() {
    use alpine::nn::{ActKind, GraphBuilder, LayerKind};
    use alpine::workload::{automap, compile};

    let dims = [784u64, 256, 64, 10];
    let reference = alpine::nn::LayerGraph::mlp(&dims);

    let mut b = GraphBuilder::new(reference.name.clone());
    let mut prev = b.input(4 * dims[0], dims[0] / 4 + 40, dims[0]);
    for l in 0..dims.len() - 1 {
        prev = b
            .layer(LayerKind::Dense { rows: dims[l], cols: dims[l + 1], weight_slot: l })
            .after(&[prev]);
        prev = b
            .layer(LayerKind::Activation { kind: ActKind::Relu, elems: dims[l + 1] })
            .after(&[prev]);
    }
    b.layer(LayerKind::Output { bytes: 4 * dims[dims.len() - 1] }).after(&[prev]);
    let built = b.finish().unwrap();
    assert_eq!(built, reference, "builder chain must equal the constructor's IR");

    let budget = alpine::workload::automap::TopologyBudget::for_config(&hp());
    let out = automap::search(&reference, &budget, &hp(), 1).unwrap();
    let a = compile::compile(&reference, &out.ranked[0].mapping, 3).unwrap();
    let b = compile::compile(&built, &out.ranked[0].mapping, 3).unwrap();
    assert_workloads_identical(&a, &b);
    assert_stats_identical(SystemKind::HighPower, a, b);
}

#[test]
fn mlp_traces_bit_identical_to_legacy() {
    for case in MLP_CASES {
        let oracle = legacy::mlp::generate(case, &hp(), 3);
        let compiled = mlp::generate(case, &hp(), 3).unwrap();
        assert_workloads_identical(&oracle, &compiled);
    }
}

#[test]
fn lstm_traces_bit_identical_to_legacy() {
    for n_h in [256u64, 512, 750] {
        for case in LSTM_CASES {
            let oracle = legacy::lstm::generate(case, n_h, &hp(), 3);
            let compiled = lstm::generate(case, n_h, &hp(), 3).unwrap();
            assert_workloads_identical(&oracle, &compiled);
        }
    }
}

#[test]
fn cnn_traces_bit_identical_to_legacy() {
    for variant in CnnVariant::ALL {
        for case in [CnnCase::Digital, CnnCase::Analog] {
            let oracle = legacy::cnn::generate(case, variant, &hp(), 2);
            let compiled = cnn::generate(case, variant, &hp(), 2).unwrap();
            assert_workloads_identical(&oracle, &compiled);
        }
    }
}

/// At inference counts past the loop threshold the compiler stores the
/// per-inference block once inside a `Rep`; its flattened stream must
/// still reproduce the legacy unrolled emission exactly.
#[test]
fn looped_traces_flatten_to_legacy_unrolled_form() {
    const N: u32 = 12; // past the warm-up + 4-pair loop threshold
    for case in MLP_CASES {
        let oracle = legacy::mlp::generate(case, &hp(), N);
        let compiled = mlp::generate(case, &hp(), N).unwrap();
        assert!(
            compiled.stored_ops() < compiled.total_ops(),
            "{}: expected a looped trace at {N} inferences",
            compiled.label
        );
        assert_workloads_identical(&oracle, &compiled);
    }
    for case in LSTM_CASES {
        let oracle = legacy::lstm::generate(case, 256, &hp(), N);
        let compiled = lstm::generate(case, 256, &hp(), N).unwrap();
        assert!(compiled.stored_ops() < compiled.total_ops(), "{}", compiled.label);
        assert_workloads_identical(&oracle, &compiled);
    }
    for case in [CnnCase::Digital, CnnCase::Analog] {
        let oracle = legacy::cnn::generate(case, CnnVariant::Fast, &hp(), 10);
        let compiled = cnn::generate(case, CnnVariant::Fast, &hp(), 10).unwrap();
        assert!(compiled.stored_ops() < compiled.total_ops(), "{}", compiled.label);
        assert_workloads_identical(&oracle, &compiled);
    }
}

/// Looped compiled traces must also *simulate* bit-identically to the
/// legacy flat oracle (fast-forward enabled, as in production sweeps).
#[test]
fn looped_runstats_bit_identical_to_legacy() {
    const N: u32 = 12;
    for case in [
        MlpCase::Digital { cores: 1 },
        MlpCase::Digital { cores: 4 },
        MlpCase::Analog { case: 3 },
        MlpCase::AnalogLoose,
    ] {
        let oracle = legacy::mlp::generate(case, &hp(), N);
        let compiled = mlp::generate(case, &hp(), N).unwrap();
        assert_stats_identical(SystemKind::HighPower, oracle, compiled);
    }
    let oracle = legacy::lstm::generate(LstmCase::Analog { case: 4 }, 512, &hp(), N);
    let compiled = lstm::generate(LstmCase::Analog { case: 4 }, 512, &hp(), N).unwrap();
    assert_stats_identical(SystemKind::HighPower, oracle, compiled);
}

#[test]
fn mlp_runstats_bit_identical_to_legacy() {
    for kind in SystemKind::ALL {
        let cfg = SystemConfig::for_kind(kind);
        for case in MLP_CASES {
            let oracle = legacy::mlp::generate(case, &cfg, 2);
            let compiled = mlp::generate(case, &cfg, 2).unwrap();
            assert_stats_identical(kind, oracle, compiled);
        }
    }
}

#[test]
fn lstm_runstats_bit_identical_to_legacy() {
    for (n_h, case) in [
        (256u64, LstmCase::Digital { cores: 1 }),
        (256, LstmCase::Digital { cores: 5 }),
        (256, LstmCase::Analog { case: 1 }),
        (512, LstmCase::Analog { case: 3 }),
        (750, LstmCase::Analog { case: 4 }),
    ] {
        let oracle = legacy::lstm::generate(case, n_h, &hp(), 2);
        let compiled = lstm::generate(case, n_h, &hp(), 2).unwrap();
        assert_stats_identical(SystemKind::HighPower, oracle, compiled);
    }
}

#[test]
fn cnn_runstats_bit_identical_to_legacy() {
    for case in [CnnCase::Digital, CnnCase::Analog] {
        let oracle = legacy::cnn::generate(case, CnnVariant::Fast, &hp(), 1);
        let compiled = cnn::generate(case, CnnVariant::Fast, &hp(), 1).unwrap();
        assert_stats_identical(SystemKind::HighPower, oracle, compiled);
    }
}
