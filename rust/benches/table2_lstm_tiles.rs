//! Bench: Table II — LSTM network parameters and AIMC tile dimensions
//! per case, our computed layouts vs the paper's published values.

use alpine::nn::lstm::{LstmModel, PAPER_TILE_DIMS, PAPER_TOTAL_PARAMS};
use alpine::util::table::Table;

fn main() {
    let mut t = Table::new(
        "Table II-A — LSTM parameters",
        &["n_h", "cell (rows x cols)", "dense", "params (ours)", "params (paper)"],
    );
    for (n_h, paper) in PAPER_TOTAL_PARAMS {
        let m = LstmModel::paper(n_h);
        t.row(vec![
            n_h.to_string(),
            format!("{}x{}", m.cell_rows(), m.cell_cols()),
            format!("{}x{}", m.dense_rows(), m.dense_cols()),
            m.total_params().to_string(),
            format!("{:.1}k", paper / 1e3),
        ]);
    }
    t.print();

    let mut t2 = Table::new(
        "Table II-B — AIMC tile dimensions (paper values, used by the generators)",
        &["n_h", "case 1", "case 2", "case 3", "case 4"],
    );
    for (n_h, dims) in PAPER_TILE_DIMS {
        let mut row = vec![n_h.to_string()];
        row.extend(dims.iter().map(|(r, c)| format!("{r} x {c}")));
        t2.row(row);
    }
    t2.print();

    let mut t3 = Table::new(
        "Working sets (§VIII.E)",
        &["n_h", "digital", "analog", "fits L1 (analog)"],
    );
    for n_h in [256u64, 512, 750] {
        let m = LstmModel::paper(n_h);
        t3.row(vec![
            n_h.to_string(),
            format!("{:.2} kB", m.working_set_digital() as f64 / 1024.0),
            format!("{:.2} kB", m.working_set_analog() as f64 / 1024.0),
            (m.working_set_analog() < 32 * 1024).to_string(),
        ]);
    }
    t3.print();
}
