//! Micro-benchmarks of trace compilation (the mapping compiler's
//! `(LayerGraph, Mapping) -> Workload` path): emission throughput in
//! ops/sec for the largest CNN case, plus the MLP/LSTM case tables, and
//! the compiler-vs-legacy-generator overhead ratio. Results land in
//! `BENCH_workloads.json` alongside `BENCH_sim.json` so the compile-path
//! perf trajectory is trackable across PRs.

use alpine::config::SystemConfig;
use alpine::nn::CnnVariant;
use alpine::util::benchkit::{bench, black_box, json_report};
use alpine::workload::automap::{self, TopologyBudget};
use alpine::workload::cnn::{self, CnnCase};
use alpine::workload::legacy;
use alpine::workload::lstm::{self, LstmCase};
use alpine::workload::mlp::{self, MlpCase};
use alpine::workload::transformer::{self, TransformerCase, TransformerShape};

fn main() {
    let cfg = SystemConfig::high_power();
    let n_inf = 3; // §VI.C CNN inference count
    let mut results = Vec::new();

    // Largest CNN case: CNN-S emits multi-megaop traces (per-pixel CM
    // ops in the analog variant, blocked GEMM groups in the digital).
    for (name, case) in [("dig", CnnCase::Digital), ("ana", CnnCase::Analog)] {
        let w = cnn::generate(case, CnnVariant::Slow, &cfg, n_inf).unwrap();
        let total_ops = w.total_ops();
        drop(w);

        let compiled = bench(&format!("workload/compile_cnn_slow_{name}"), 10, || {
            black_box(cnn::generate(case, CnnVariant::Slow, &cfg, n_inf).unwrap());
        });
        println!(
            "workload/compile_cnn_slow_{name}: {:.1} Mops/s emitted ({} ops per compile)",
            total_ops as f64 / (compiled.mean_ns / 1e9) / 1e6,
            total_ops
        );
        let legacy_gen = bench(&format!("workload/legacy_cnn_slow_{name}"), 10, || {
            black_box(legacy::cnn::generate(case, CnnVariant::Slow, &cfg, n_inf));
        });
        println!(
            "workload/compile_cnn_slow_{name}: compiler vs legacy generator {:.2}x (mean, <1 = compiler faster)",
            compiled.mean_ns / legacy_gen.mean_ns
        );
        results.push(compiled);
        results.push(legacy_gen);
    }

    // Looped-IR compile scaling (PR 4): the steady state is emitted once
    // into a `Rep` body, so compile work is O(block) in the inference
    // count while the legacy generator unrolls all N blocks.
    {
        let w = mlp::generate(MlpCase::Digital { cores: 1 }, &cfg, 1000).unwrap();
        println!(
            "workload/compile_mlp_dig1_1000inf_looped: {} stored ops for {} flattened ops",
            w.stored_ops(),
            w.total_ops()
        );
        drop(w);
        let looped = bench("workload/compile_mlp_dig1_1000inf_looped", 20, || {
            black_box(mlp::generate(MlpCase::Digital { cores: 1 }, &cfg, 1000).unwrap());
        });
        let unrolled = bench("workload/legacy_mlp_dig1_1000inf_unrolled", 5, || {
            black_box(legacy::mlp::generate(MlpCase::Digital { cores: 1 }, &cfg, 1000));
        });
        println!(
            "workload/compile_mlp_dig1_1000inf_looped: looped vs legacy-unrolled {:.2}x faster (mean)",
            unrolled.mean_ns / looped.mean_ns
        );
        results.push(looped);
        results.push(unrolled);
    }

    // Case-table compile throughput for the smaller paper workloads.
    results.push(bench("workload/compile_mlp_ana4", 50, || {
        black_box(mlp::generate(MlpCase::Analog { case: 4 }, &cfg, 10).unwrap());
    }));
    results.push(bench("workload/compile_lstm_ana4_750", 50, || {
        black_box(lstm::generate(LstmCase::Analog { case: 4 }, 750, &cfg, 10).unwrap());
    }));
    results.push(bench("workload/compile_mlp_custom_pipe3", 50, || {
        let shape = mlp::MlpShape::parse("784x512x512x10").unwrap();
        black_box(
            mlp::generate_custom(shape, mlp::CustomMlpMapping::Analog { tiles: 3, pipeline: true }, 10)
                .unwrap(),
        );
    }));

    // Transformer-encoder compile throughput (new workload class).
    let tshape = TransformerShape::new(256, 4, 64, 2, 1024).unwrap();
    results.push(bench("workload/compile_transformer_ana", 50, || {
        black_box(transformer::generate(tshape, TransformerCase::Analog, 10).unwrap());
    }));

    // Automap search throughput: enumerate + cost-prune the full mapping
    // space of a 2-layer encoder (no simulation) under a Table-I budget.
    let tgraph = tshape.graph();
    let budget = TopologyBudget { cores: 8, tiles: 16, tile_rows: 256, tile_cols: 256, channels: 64 };
    let searched = bench("workload/automap_search_transformer_l2", 5, || {
        black_box(automap::search(&tgraph, &budget, &cfg, 8).unwrap());
    });
    let outcome = automap::search(&tgraph, &budget, &cfg, 8).unwrap();
    println!(
        "workload/automap_search_transformer_l2: {} enumerated, {} feasible, {:.1} candidates/ms",
        outcome.enumerated,
        outcome.feasible,
        outcome.enumerated as f64 / (searched.mean_ns / 1e6)
    );
    results.push(searched);

    json_report(&results, "BENCH_workloads.json").expect("writing BENCH_workloads.json");
}
