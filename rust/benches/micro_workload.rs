//! Micro-benchmarks of trace compilation (the mapping compiler's
//! `(LayerGraph, Mapping) -> Workload` path): emission throughput in
//! ops/sec for the largest CNN case, plus the MLP/LSTM case tables, and
//! the compiler-vs-legacy-generator overhead ratio. Results land in
//! `BENCH_workloads.json` alongside `BENCH_sim.json` so the compile-path
//! perf trajectory is trackable across PRs.

use alpine::config::SystemConfig;
use alpine::nn::{CnnVariant, LayerGraph};
use alpine::util::benchkit::{bench, black_box, json_report, BenchResult};
use alpine::workload::automap::{self, CostModel, SearchOptions, TopologyBudget};
use alpine::workload::cnn::{self, CnnCase};
use alpine::workload::legacy;
use alpine::workload::lstm::{self, LstmCase};
use alpine::workload::mlp::{self, MlpCase};
use alpine::workload::transformer::{self, TransformerCase, TransformerShape};

fn main() {
    let cfg = SystemConfig::high_power();
    let n_inf = 3; // §VI.C CNN inference count
    let mut results = Vec::new();

    // Largest CNN case: CNN-S emits multi-megaop traces (per-pixel CM
    // ops in the analog variant, blocked GEMM groups in the digital).
    for (name, case) in [("dig", CnnCase::Digital), ("ana", CnnCase::Analog)] {
        let w = cnn::generate(case, CnnVariant::Slow, &cfg, n_inf).unwrap();
        let total_ops = w.total_ops();
        drop(w);

        let compiled = bench(&format!("workload/compile_cnn_slow_{name}"), 10, || {
            black_box(cnn::generate(case, CnnVariant::Slow, &cfg, n_inf).unwrap());
        });
        println!(
            "workload/compile_cnn_slow_{name}: {:.1} Mops/s emitted ({} ops per compile)",
            total_ops as f64 / (compiled.mean_ns / 1e9) / 1e6,
            total_ops
        );
        let legacy_gen = bench(&format!("workload/legacy_cnn_slow_{name}"), 10, || {
            black_box(legacy::cnn::generate(case, CnnVariant::Slow, &cfg, n_inf));
        });
        println!(
            "workload/compile_cnn_slow_{name}: compiler vs legacy generator {:.2}x (mean, <1 = compiler faster)",
            compiled.mean_ns / legacy_gen.mean_ns
        );
        results.push(compiled);
        results.push(legacy_gen);
    }

    // Looped-IR compile scaling (PR 4): the steady state is emitted once
    // into a `Rep` body, so compile work is O(block) in the inference
    // count while the legacy generator unrolls all N blocks.
    {
        let w = mlp::generate(MlpCase::Digital { cores: 1 }, &cfg, 1000).unwrap();
        println!(
            "workload/compile_mlp_dig1_1000inf_looped: {} stored ops for {} flattened ops",
            w.stored_ops(),
            w.total_ops()
        );
        drop(w);
        let looped = bench("workload/compile_mlp_dig1_1000inf_looped", 20, || {
            black_box(mlp::generate(MlpCase::Digital { cores: 1 }, &cfg, 1000).unwrap());
        });
        let unrolled = bench("workload/legacy_mlp_dig1_1000inf_unrolled", 5, || {
            black_box(legacy::mlp::generate(MlpCase::Digital { cores: 1 }, &cfg, 1000));
        });
        println!(
            "workload/compile_mlp_dig1_1000inf_looped: looped vs legacy-unrolled {:.2}x faster (mean)",
            unrolled.mean_ns / looped.mean_ns
        );
        results.push(looped);
        results.push(unrolled);
    }

    // Case-table compile throughput for the smaller paper workloads.
    results.push(bench("workload/compile_mlp_ana4", 50, || {
        black_box(mlp::generate(MlpCase::Analog { case: 4 }, &cfg, 10).unwrap());
    }));
    results.push(bench("workload/compile_lstm_ana4_750", 50, || {
        black_box(lstm::generate(LstmCase::Analog { case: 4 }, 750, &cfg, 10).unwrap());
    }));
    results.push(bench("workload/compile_mlp_custom_pipe3", 50, || {
        let shape = mlp::MlpShape::parse("784x512x512x10").unwrap();
        black_box(
            mlp::generate_custom(shape, mlp::CustomMlpMapping::Analog { tiles: 3, pipeline: true }, 10)
                .unwrap(),
        );
    }));

    // Transformer-encoder compile throughput (new workload class).
    let tshape = TransformerShape::new(256, 4, 64, 2, 1024).unwrap();
    results.push(bench("workload/compile_transformer_ana", 50, || {
        black_box(transformer::generate(tshape, TransformerCase::Analog, 10).unwrap());
    }));

    // Automap search: the per-candidate-compile oracle vs the
    // compositional engine on the SAME space (the legacy clipped walk:
    // depth <= 6, replication <= 4, 60k cap — today's configuration),
    // then the compositional branch-and-bound over the ENLARGED space
    // (depth <= 8, replication <= 8, uncapped). ISSUE-5 acceptance:
    // compositional >= 10x over compiled end-to-end, and the enlarged
    // search finishes faster than today's capped one.
    let budget = TopologyBudget { cores: 8, tiles: 16, tile_rows: 256, tile_cols: 256, channels: 64 };
    let legacy_space = |model: CostModel| SearchOptions {
        top_k: 8,
        model,
        cap: Some(60_000),
        max_depth: 6,
        max_replica: 4,
        jobs: 1,
        // The compiled legs time the *uncached* oracle so the ISSUE-5
        // compiled-vs-compositional ratio keeps its meaning; the PR-7
        // cache leg below measures its win against this same baseline.
        compile_cache: false,
    };
    let enlarged = SearchOptions { top_k: 8, ..SearchOptions::default() };
    let search_pair = |tag: &str,
                       graph: &LayerGraph,
                       iters_compiled: u32,
                       cache_floor: f64,
                       results: &mut Vec<BenchResult>| {
        // Equal iteration counts on every leg: min-of-3 vs min-of-10
        // would bias the asserted ratios leniently.
        let compiled = bench(&format!("automap/search_{tag}_compiled"), iters_compiled, || {
            black_box(
                automap::search_opts(graph, &budget, &cfg, &legacy_space(CostModel::Compiled)).unwrap(),
            );
        });
        let compositional = bench(&format!("automap/search_{tag}_compositional"), iters_compiled, || {
            black_box(
                automap::search_opts(graph, &budget, &cfg, &legacy_space(CostModel::Compositional))
                    .unwrap(),
            );
        });
        let bnb = bench(&format!("automap/search_{tag}_enlarged_bnb"), iters_compiled, || {
            black_box(automap::search_opts(graph, &budget, &cfg, &enlarged).unwrap());
        });
        let out = automap::search_opts(graph, &budget, &cfg, &enlarged).unwrap();
        println!(
            "automap/search_{tag}: {} enumerated / {} pruned / {} feasible over the enlarged space; \
             compiled-vs-compositional {:.1}x (mean), {:.1}x (min); enlarged B&B vs legacy compiled {:.1}x (min)",
            out.enumerated,
            out.pruned,
            out.feasible,
            compiled.mean_ns / compositional.mean_ns,
            compiled.min_ns / compositional.min_ns,
            compiled.min_ns / bnb.min_ns,
        );
        // Acceptance floor (ISSUE-5): eliminating the per-candidate
        // compile must buy >= 10x end-to-end on the same space, and the
        // *enlarged* search must still beat today's capped one.
        assert!(
            compiled.min_ns / compositional.min_ns >= 10.0,
            "automap/search_{tag}: compositional speedup {:.2}x below the 10x floor",
            compiled.min_ns / compositional.min_ns,
        );
        assert!(
            bnb.min_ns < compiled.min_ns,
            "automap/search_{tag}: enlarged branch-and-bound search ({:.1} ms) slower than the legacy capped compiled search ({:.1} ms)",
            bnb.min_ns / 1e6,
            compiled.min_ns / 1e6,
        );
        results.push(BenchResult {
            name: format!("automap/search_{tag}_speedup_x"),
            mean_ns: compiled.mean_ns / compositional.mean_ns,
            min_ns: compiled.min_ns / compositional.min_ns,
            stddev_ns: 0.0,
            iters: 1,
        });

        // Cross-candidate compile cache (ISSUE-7): the same compiled
        // oracle on the same space, with step fragments shared across
        // candidates. Scores are bit-identical either way (checked
        // first); the ratio is the cache's end-to-end search win.
        let cached_opts = SearchOptions { compile_cache: true, ..legacy_space(CostModel::Compiled) };
        let on_out = automap::search_opts(graph, &budget, &cfg, &cached_opts).unwrap();
        let off_out =
            automap::search_opts(graph, &budget, &cfg, &legacy_space(CostModel::Compiled)).unwrap();
        let key = |out: &automap::SearchOutcome| {
            out.ranked
                .iter()
                .map(|c| (c.desc.clone(), c.est.cycles_per_inf.to_bits()))
                .collect::<Vec<_>>()
        };
        assert_eq!(
            key(&on_out),
            key(&off_out),
            "automap/search_{tag}: cache-on ranking diverged from cache-off",
        );
        let cached = bench(&format!("automap/search_{tag}_compiled_cached"), iters_compiled, || {
            black_box(automap::search_opts(graph, &budget, &cfg, &cached_opts).unwrap());
        });
        let stats = on_out.cache.expect("cache-enabled compiled search reports stats");
        println!(
            "automap/search_{tag}: compile cache on-vs-off {:.1}x (mean), {:.1}x (min); \
             {} hits / {} misses, {:.1} KiB fragment arena",
            compiled.mean_ns / cached.mean_ns,
            compiled.min_ns / cached.min_ns,
            stats.hits,
            stats.misses,
            stats.arena_bytes as f64 / 1024.0,
        );
        // Acceptance floor (ISSUE-7): keying out the repeated fragment
        // emission must buy >= 5x end-to-end on the same space.
        if cache_floor > 0.0 {
            assert!(
                compiled.min_ns / cached.min_ns >= cache_floor,
                "automap/search_{tag}: compile-cache speedup {:.2}x below the {cache_floor}x floor",
                compiled.min_ns / cached.min_ns,
            );
        }
        results.push(BenchResult {
            name: format!("automap/search_{tag}_cache_speedup_x"),
            mean_ns: compiled.mean_ns / cached.mean_ns,
            min_ns: compiled.min_ns / cached.min_ns,
            stddev_ns: 0.0,
            iters: 1,
        });
        results.push(compiled);
        results.push(compositional);
        results.push(bnb);
        results.push(cached);
    };
    // The paper transformer budget (the bench-regression reference case).
    let tgraph = tshape.graph();
    search_pair("transformer", &tgraph, 3, 5.0, &mut results);
    // A custom deep MLP — the second enlarged-space demonstration. No
    // enforced cache floor (its space is thinner on analog fragments);
    // the ratio is tracked in BENCH_workloads.json.
    let mlp_graph = LayerGraph::mlp(&[784, 512, 256, 128, 10]);
    search_pair("custom_mlp", &mlp_graph, 5, 0.0, &mut results);

    json_report(&results, "BENCH_workloads.json").expect("writing BENCH_workloads.json");
}
