//! Bench: regenerate Fig. 13 — CNN-F/M/S aggregate results, 8-core
//! pipelined DIG vs ANA on both systems. Paper headline: up to 20.5x
//! speedup / 20.8x energy / 3.7x memory-intensity improvement for CNN-S
//! on the high-power system.

use alpine::config::SystemKind;
use alpine::coordinator::experiments;
use alpine::report;

fn main() {
    let rows = experiments::fig13_cnn(experiments::CNN_INFERENCES).unwrap();
    report::aggregate_table("Fig. 13 — CNN aggregate (3 inferences)", &rows).print();

    for sys in SystemKind::ALL {
        for variant in ["CNN-F", "CNN-M", "CNN-S"] {
            let pair: Vec<_> = rows
                .iter()
                .filter(|r| r.system == sys && r.label.contains(variant))
                .cloned()
                .collect();
            if pair.len() == 2 {
                let dig = pair.iter().find(|r| r.label.ends_with("DIG")).unwrap();
                let ana = pair.iter().find(|r| r.label.ends_with("ANA")).unwrap();
                println!(
                    "{variant} [{}]: speedup {:.1}x, energy gain {:.1}x, LLCMPI improvement {:.1}x",
                    sys.name(),
                    dig.time_s / ana.time_s,
                    dig.energy.total_j() / ana.energy.total_j(),
                    dig.llc_mpki / ana.llc_mpki.max(1e-9),
                );
            }
        }
    }
}
