//! Micro-benchmarks of the simulator substrate itself (the §Perf hot
//! paths): cache lookups, trace-machine streaming throughput on both the
//! batched fast path and the per-line reference path, and the AIMClib
//! functional MVM. Results land in `BENCH_sim.json` (name -> mean/min
//! ns) so the perf trajectory is trackable across PRs.

use alpine::aimclib::checker::{self, Matrix};
use alpine::config::SystemConfig;
use alpine::nn::CnnVariant;
use alpine::sim::cache::{Access, Cache};
use alpine::sim::machine::{Machine, MachineSpec, TileSpec};
use alpine::sim::{Coupling, TileFaultModel};
use alpine::util::benchkit::{bench, black_box, json_report, BenchResult};
use alpine::util::rng::Rng;
use alpine::workload::cnn::{self, CnnCase};
use alpine::workload::mlp::{self, MlpCase};
use alpine::workload::trace::TraceBuilder;
use alpine::workload::Workload;

/// The 64 MiB cold-stream trace: 16 x 4 MiB regions, all L1/LLC misses.
fn stream_64mb_trace() -> Vec<alpine::workload::trace::TraceOp> {
    let mut b = TraceBuilder::new();
    for k in 0..16u64 {
        b.stream_read(0x1000_0000 + k * 0x40_0000, 4 * 1024 * 1024, 1);
    }
    b.build()
}

/// An L1-resident re-streaming trace: warm 16 KiB once, re-read it 256x.
fn stream_hits_trace() -> Vec<alpine::workload::trace::TraceOp> {
    let mut b = TraceBuilder::new();
    for _ in 0..257 {
        b.stream_read(0x2000_0000, 16 * 1024, 1);
    }
    b.build()
}

fn main() {
    let mut results = Vec::new();

    // Cache lookup throughput (hit-heavy).
    let cfg = SystemConfig::high_power();
    let mut cache = Cache::new(cfg.l1d);
    for addr in (0..32 * 1024).step_by(64) {
        cache.access(addr, Access::Read);
    }
    results.push(bench("cache/l1_hits_1M", 10, || {
        for _ in 0..4 {
            for addr in (0..16 * 1024 * 16).step_by(64) {
                black_box(cache.access(black_box(addr % (32 * 1024)), Access::Read));
            }
        }
    }));

    // Miss-heavy streaming through the full hierarchy via the machine:
    // batched bulk path (default) vs the per-line reference loop. The
    // two produce bit-identical RunStats (asserted below); the ratio is
    // the PR's headline fast-path speedup.
    let trace = stream_64mb_trace();
    let run_stream = |batched: bool, trace: &[alpine::workload::trace::TraceOp]| {
        let mut m = Machine::new(SystemConfig::high_power(), MachineSpec::default());
        m.set_batched_streams(batched);
        m.run(vec![trace.to_vec()]).unwrap()
    };
    let fast = run_stream(true, &trace);
    let reference = run_stream(false, &trace);
    assert_eq!(fast.roi_time_ps, reference.roi_time_ps, "paths must agree");
    assert_eq!(fast.dram_accesses, reference.dram_accesses, "paths must agree");

    let batched = bench("machine/stream_64MB_lines", 5, || {
        black_box(run_stream(true, &trace));
    });
    let per_line = bench("machine/stream_64MB_lines_perline", 5, || {
        black_box(run_stream(false, &trace));
    });
    println!(
        "machine/stream_64MB_lines: batched vs per-line speedup {:.2}x (mean), {:.2}x (min)",
        per_line.mean_ns / batched.mean_ns,
        per_line.min_ns / batched.min_ns,
    );
    results.push(batched);
    results.push(per_line);

    // Fault-hook overhead on the disabled path (PR 6): the same 64 MiB
    // stream on a machine that carries a tile with an explicit — but
    // inactive — `TileFaultModel::none()`. The fault checks are gated
    // behind an `is_none()` early-out outside the streaming hot loop, so
    // the disabled path must cost < 1% over the plain run. Compared on
    // min_ns (the noise-robust statistic) against a same-shape baseline.
    let tiled_spec = MachineSpec {
        tiles: vec![TileSpec { rows: 256, cols: 256, coupling: Coupling::Tight }],
        ..MachineSpec::default()
    };
    let run_stream_tiled = |hooked: bool, trace: &[alpine::workload::trace::TraceOp]| {
        let mut m = Machine::new(SystemConfig::high_power(), tiled_spec.clone());
        if hooked {
            m.set_tile_fault(0, TileFaultModel::none());
        }
        m.run(vec![trace.to_vec()]).unwrap()
    };
    let plain = bench("machine/stream_64MB_lines_nofault_base", 5, || {
        black_box(run_stream_tiled(false, &trace));
    });
    let hooked = bench("machine/stream_64MB_lines_faults_disabled", 5, || {
        black_box(run_stream_tiled(true, &trace));
    });
    let overhead = hooked.min_ns / plain.min_ns;
    println!(
        "machine/stream_64MB_lines: faults-disabled overhead {:.4}x (min), {:.4}x (mean)",
        overhead,
        hooked.mean_ns / plain.mean_ns,
    );
    assert!(
        overhead < 1.01,
        "faults-disabled path costs {overhead:.4}x over baseline (>1% overhead)",
    );
    results.push(BenchResult {
        name: "machine/stream_64MB_lines_fault_overhead_x".to_string(),
        mean_ns: hooked.mean_ns / plain.mean_ns,
        min_ns: overhead,
        stddev_ns: 0.0,
        iters: 1,
    });
    results.push(plain);
    results.push(hooked);

    // Hit-heavy streaming (L1-resident working set): the bulk walk's
    // early-out case.
    let hits_trace = stream_hits_trace();
    let batched_hits = bench("machine/stream_l1_resident_hits", 5, || {
        black_box(run_stream(true, &hits_trace));
    });
    let per_line_hits = bench("machine/stream_l1_resident_hits_perline", 5, || {
        black_box(run_stream(false, &hits_trace));
    });
    println!(
        "machine/stream_l1_resident_hits: batched vs per-line speedup {:.2}x (mean)",
        per_line_hits.mean_ns / batched_hits.mean_ns,
    );
    results.push(batched_hits);
    results.push(per_line_hits);

    // Steady-state fast-forward vs full replay (PR 4): looped traces
    // store one `Rep` body; the fast path detects per-inference
    // periodicity and jumps the steady state in closed form. Stats are
    // asserted bit-identical before timing; the speedup ratios are
    // persisted to BENCH_sim.json as synthetic entries.
    let run_w = |w: &Workload, ff: bool| {
        let mut m = Machine::new(SystemConfig::high_power(), w.spec.clone());
        m.set_fast_forward(ff);
        m.run(w.traces.clone()).unwrap()
    };
    let mut ff_case = |results: &mut Vec<BenchResult>,
                       tag: &str,
                       w: &Workload,
                       iters_ff: u32,
                       iters_replay: u32,
                       min_ratio: f64| {
        let fast = run_w(w, true);
        let reference = run_w(w, false);
        fast.assert_bit_identical(&reference, tag);
        let ff = bench(&format!("machine/{tag}_fastforward"), iters_ff, || {
            black_box(run_w(w, true));
        });
        let replay = bench(&format!("machine/{tag}_replay"), iters_replay, || {
            black_box(run_w(w, false));
        });
        println!(
            "machine/{tag}: fast-forward vs replay speedup {:.2}x (mean), {:.2}x (min)",
            replay.mean_ns / ff.mean_ns,
            replay.min_ns / ff.min_ns,
        );
        // Acceptance floor: the ratio persisted to BENCH_sim.json is far
        // below the regression gate's noise floor, so enforce it here —
        // the bench binary itself fails if fast-forward stops engaging.
        assert!(
            replay.min_ns / ff.min_ns >= min_ratio,
            "machine/{tag}: fast-forward speedup {:.2}x below the {min_ratio}x floor",
            replay.min_ns / ff.min_ns,
        );
        results.push(BenchResult {
            name: format!("machine/{tag}_ff_speedup_x"),
            mean_ns: replay.mean_ns / ff.mean_ns,
            min_ns: replay.min_ns / ff.min_ns,
            stddev_ns: 0.0,
            iters: 1,
        });
        results.push(ff);
        results.push(replay);
    };
    let cfg = SystemConfig::high_power();
    // The acceptance case: a 1000-inference sweep of the largest MLP
    // case (the digital reference streams the full 2 MiB weight set per
    // inference).
    let mlp_w = mlp::generate(MlpCase::Digital { cores: 1 }, &cfg, 1000).unwrap();
    ff_case(&mut results, "mlp_dig1_1000inf", &mlp_w, 5, 3, 5.0);

    // Nested-periodicity fast-forward (PR 7): a 64-inference digital
    // CNN-F pipeline (8 cores, row-streamed channels) whose trace
    // carries per-row `Rep` loops *inside* the inference loop. The
    // per-segment cursor stack detects periodicity at both nesting
    // levels, so jumps engage where the flat single-level detector
    // stalled on the pipeline's fill transient. All three paths are
    // asserted bit-identical before timing; both the replay ratio (the
    // ISSUE-7 >= 5x acceptance floor) and the nested-vs-flat gain are
    // persisted to BENCH_sim.json.
    let cnn_w = cnn::generate(CnnCase::Digital, CnnVariant::Fast, &cfg, 64).unwrap();
    let run_nested = |w: &Workload, ff: bool, nested: bool| {
        let mut m = Machine::new(SystemConfig::high_power(), w.spec.clone());
        m.set_fast_forward(ff);
        m.set_nested_fast_forward(nested);
        m.run(w.traces.clone()).unwrap()
    };
    let nested_stats = run_nested(&cnn_w, true, true);
    let flat_stats = run_nested(&cnn_w, true, false);
    let reference = run_nested(&cnn_w, false, false);
    nested_stats.assert_bit_identical(&reference, "cnn_fast_dig_64inf nested-ff");
    flat_stats.assert_bit_identical(&reference, "cnn_fast_dig_64inf flat-ff");
    let b_nested = bench("machine/cnn_fast_dig_64inf_fastforward", 3, || {
        black_box(run_nested(&cnn_w, true, true));
    });
    let b_flat = bench("machine/cnn_fast_dig_64inf_flat_ff", 3, || {
        black_box(run_nested(&cnn_w, true, false));
    });
    let b_replay = bench("machine/cnn_fast_dig_64inf_replay", 3, || {
        black_box(run_nested(&cnn_w, false, false));
    });
    println!(
        "machine/cnn_fast_dig_64inf: nested-ff vs replay {:.2}x (mean), {:.2}x (min); \
         nested-ff vs flat-ff {:.2}x (min)",
        b_replay.mean_ns / b_nested.mean_ns,
        b_replay.min_ns / b_nested.min_ns,
        b_flat.min_ns / b_nested.min_ns,
    );
    assert!(
        b_replay.min_ns / b_nested.min_ns >= 5.0,
        "machine/cnn_fast_dig_64inf: nested fast-forward speedup {:.2}x below the 5x floor",
        b_replay.min_ns / b_nested.min_ns,
    );
    results.push(BenchResult {
        name: "machine/cnn_fast_dig_64inf_ff_speedup_x".to_string(),
        mean_ns: b_replay.mean_ns / b_nested.mean_ns,
        min_ns: b_replay.min_ns / b_nested.min_ns,
        stddev_ns: 0.0,
        iters: 1,
    });
    results.push(BenchResult {
        name: "machine/cnn_fast_dig_64inf_nested_gain_x".to_string(),
        mean_ns: b_flat.mean_ns / b_nested.mean_ns,
        min_ns: b_flat.min_ns / b_nested.min_ns,
        stddev_ns: 0.0,
        iters: 1,
    });
    results.push(b_nested);
    results.push(b_flat);
    results.push(b_replay);

    // AIMClib functional MVM (the checker used in e2e validation).
    let mut rng = Rng::new(1);
    let x = Matrix::new(1, 1024, (0..1024).map(|_| rng.normal_f32(1.0)).collect());
    let w = Matrix::new(1024, 1024, (0..1024 * 1024).map(|_| rng.normal_f32(0.1)).collect());
    let (w_q, _) = checker::quantize_weights(&w);
    let spec = checker::AimcSpec {
        in_scale: 0.01,
        w_scale: 0.001,
        adc_scale: 100.0,
        tile_rows: 256,
        tile_cols: 256,
    };
    results.push(bench("aimclib/checker_mvm_1024x1024", 10, || {
        black_box(checker::aimc_mvm(&x, &w_q, &spec));
    }));

    json_report(&results, "BENCH_sim.json").expect("writing BENCH_sim.json");
}
