//! Micro-benchmarks of the simulator substrate itself (the §Perf hot
//! paths): cache lookups, DRAM channel accounting, trace-machine
//! streaming throughput, AIMClib functional MVM.

use alpine::aimclib::checker::{self, Matrix};
use alpine::config::SystemConfig;
use alpine::sim::cache::{Access, Cache};
use alpine::sim::machine::{Machine, MachineSpec};
use alpine::util::benchkit::{bench, black_box};
use alpine::util::rng::Rng;
use alpine::workload::trace::TraceBuilder;

fn main() {
    // Cache lookup throughput (hit-heavy).
    let cfg = SystemConfig::high_power();
    let mut cache = Cache::new(cfg.l1d);
    for addr in (0..32 * 1024).step_by(64) {
        cache.access(addr, Access::Read);
    }
    bench("cache/l1_hits_1M", 10, || {
        for _ in 0..4 {
            for addr in (0..16 * 1024 * 16).step_by(64) {
                black_box(cache.access(black_box(addr % (32 * 1024)), Access::Read));
            }
        }
    });

    // Miss-heavy streaming through the full hierarchy via the machine.
    bench("machine/stream_64MB_lines", 5, || {
        let mut m = Machine::new(SystemConfig::high_power(), MachineSpec::default());
        let mut b = TraceBuilder::new();
        for k in 0..16u64 {
            b.stream_read(0x1000_0000 + k * 0x40_0000, 4 * 1024 * 1024, 1);
        }
        black_box(m.run(vec![b.build()]));
    });

    // AIMClib functional MVM (the checker used in e2e validation).
    let mut rng = Rng::new(1);
    let x = Matrix::new(1, 1024, (0..1024).map(|_| rng.normal_f32(1.0)).collect());
    let w = Matrix::new(1024, 1024, (0..1024 * 1024).map(|_| rng.normal_f32(0.1)).collect());
    let (w_q, _) = checker::quantize_weights(&w);
    let spec = checker::AimcSpec {
        in_scale: 0.01,
        w_scale: 0.001,
        adc_scale: 100.0,
        tile_rows: 256,
        tile_cols: 256,
    };
    bench("aimclib/checker_mvm_1024x1024", 10, || {
        black_box(checker::aimc_mvm(&x, &w_q, &spec));
    });
}
