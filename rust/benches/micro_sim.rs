//! Micro-benchmarks of the simulator substrate itself (the §Perf hot
//! paths): cache lookups, trace-machine streaming throughput on both the
//! batched fast path and the per-line reference path, and the AIMClib
//! functional MVM. Results land in `BENCH_sim.json` (name -> mean/min
//! ns) so the perf trajectory is trackable across PRs.

use alpine::aimclib::checker::{self, Matrix};
use alpine::config::SystemConfig;
use alpine::sim::cache::{Access, Cache};
use alpine::sim::machine::{Machine, MachineSpec};
use alpine::util::benchkit::{bench, black_box, json_report};
use alpine::util::rng::Rng;
use alpine::workload::trace::TraceBuilder;

/// The 64 MiB cold-stream trace: 16 x 4 MiB regions, all L1/LLC misses.
fn stream_64mb_trace() -> Vec<alpine::workload::trace::TraceOp> {
    let mut b = TraceBuilder::new();
    for k in 0..16u64 {
        b.stream_read(0x1000_0000 + k * 0x40_0000, 4 * 1024 * 1024, 1);
    }
    b.build()
}

/// An L1-resident re-streaming trace: warm 16 KiB once, re-read it 256x.
fn stream_hits_trace() -> Vec<alpine::workload::trace::TraceOp> {
    let mut b = TraceBuilder::new();
    for _ in 0..257 {
        b.stream_read(0x2000_0000, 16 * 1024, 1);
    }
    b.build()
}

fn main() {
    let mut results = Vec::new();

    // Cache lookup throughput (hit-heavy).
    let cfg = SystemConfig::high_power();
    let mut cache = Cache::new(cfg.l1d);
    for addr in (0..32 * 1024).step_by(64) {
        cache.access(addr, Access::Read);
    }
    results.push(bench("cache/l1_hits_1M", 10, || {
        for _ in 0..4 {
            for addr in (0..16 * 1024 * 16).step_by(64) {
                black_box(cache.access(black_box(addr % (32 * 1024)), Access::Read));
            }
        }
    }));

    // Miss-heavy streaming through the full hierarchy via the machine:
    // batched bulk path (default) vs the per-line reference loop. The
    // two produce bit-identical RunStats (asserted below); the ratio is
    // the PR's headline fast-path speedup.
    let trace = stream_64mb_trace();
    let run_stream = |batched: bool, trace: &[alpine::workload::trace::TraceOp]| {
        let mut m = Machine::new(SystemConfig::high_power(), MachineSpec::default());
        m.set_batched_streams(batched);
        m.run(vec![trace.to_vec()])
    };
    let fast = run_stream(true, &trace);
    let reference = run_stream(false, &trace);
    assert_eq!(fast.roi_time_ps, reference.roi_time_ps, "paths must agree");
    assert_eq!(fast.dram_accesses, reference.dram_accesses, "paths must agree");

    let batched = bench("machine/stream_64MB_lines", 5, || {
        black_box(run_stream(true, &trace));
    });
    let per_line = bench("machine/stream_64MB_lines_perline", 5, || {
        black_box(run_stream(false, &trace));
    });
    println!(
        "machine/stream_64MB_lines: batched vs per-line speedup {:.2}x (mean), {:.2}x (min)",
        per_line.mean_ns / batched.mean_ns,
        per_line.min_ns / batched.min_ns,
    );
    results.push(batched);
    results.push(per_line);

    // Hit-heavy streaming (L1-resident working set): the bulk walk's
    // early-out case.
    let hits_trace = stream_hits_trace();
    let batched_hits = bench("machine/stream_l1_resident_hits", 5, || {
        black_box(run_stream(true, &hits_trace));
    });
    let per_line_hits = bench("machine/stream_l1_resident_hits_perline", 5, || {
        black_box(run_stream(false, &hits_trace));
    });
    println!(
        "machine/stream_l1_resident_hits: batched vs per-line speedup {:.2}x (mean)",
        per_line_hits.mean_ns / batched_hits.mean_ns,
    );
    results.push(batched_hits);
    results.push(per_line_hits);

    // AIMClib functional MVM (the checker used in e2e validation).
    let mut rng = Rng::new(1);
    let x = Matrix::new(1, 1024, (0..1024).map(|_| rng.normal_f32(1.0)).collect());
    let w = Matrix::new(1024, 1024, (0..1024 * 1024).map(|_| rng.normal_f32(0.1)).collect());
    let (w_q, _) = checker::quantize_weights(&w);
    let spec = checker::AimcSpec {
        in_scale: 0.01,
        w_scale: 0.001,
        adc_scale: 100.0,
        tile_rows: 256,
        tile_cols: 256,
    };
    results.push(bench("aimclib/checker_mvm_1024x1024", 10, || {
        black_box(checker::aimc_mvm(&x, &w_q, &spec));
    }));

    json_report(&results, "BENCH_sim.json").expect("writing BENCH_sim.json");
}
