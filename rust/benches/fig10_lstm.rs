//! Bench: regenerate Fig. 10 — LSTM aggregate results for n_h in
//! {256, 512, 750}, DIG 1/2/5-core vs ANA cases 1-4, both systems.
//! The paper's headline: up to 9.4x/9.3x time/energy gains at n_h=750,
//! shrinking to ~1.0-1.5x at n_h=256 (working set fits caches).

use alpine::config::SystemKind;
use alpine::coordinator::experiments;
use alpine::report;

fn main() {
    let rows = experiments::fig10_lstm(experiments::LSTM_INFERENCES).unwrap();
    report::aggregate_table("Fig. 10 — LSTM aggregate (10 inferences)", &rows).print();

    // Per-size gains vs the single-core digital reference (high-power).
    for n_h in experiments::LSTM_SIZES {
        let sized: Vec<_> = rows
            .iter()
            .filter(|r| r.system == SystemKind::HighPower && r.label.starts_with(&format!("lstm{n_h}/")))
            .cloned()
            .collect();
        report::gains_table(
            &format!("Fig. 10 — gains vs DIG-1core, n_h={n_h} (high-power)"),
            &sized,
            |r| r.label.ends_with("DIG-1core"),
        )
        .print();
    }
}
