//! Bench: regenerate Fig. 7 — MLP aggregate results (total time, memory
//! intensity, energy) for DIG 1/2/4-core and ANA cases 1-4 on both the
//! high-power and low-power systems, plus the gains table whose maxima
//! are the paper's 12.8x/12.5x MLP headline.

use alpine::coordinator::experiments;
use alpine::report;
use alpine::util::benchkit;

fn main() {
    let rows = experiments::fig7_mlp(experiments::MLP_INFERENCES).unwrap();
    report::aggregate_table("Fig. 7 — MLP aggregate (10 inferences)", &rows).print();
    report::gains_table("Fig. 7 — gains vs DIG-1core", &rows, |r| {
        r.label.contains("DIG-1core")
    })
    .print();

    // Simulator throughput for this sweep (meta-benchmark).
    benchkit::bench("sim/fig7_full_sweep", 3, || {
        benchkit::black_box(experiments::fig7_mlp(2).unwrap());
    });
}
