//! Bench: §VII.B — loosely-coupled AIMC accelerator vs the tightly-
//! coupled ISA-extension integration vs the digital reference. Paper
//! numbers: loose achieves 4.1x over DIG-1core but is up to 3.1x slower
//! than tight coupling.

use alpine::config::SystemKind;
use alpine::coordinator::experiments;
use alpine::report;

fn main() {
    let rows = experiments::loose_vs_tight(experiments::MLP_INFERENCES).unwrap();
    report::aggregate_table("§VII.B — coupling comparison (MLP)", &rows).print();

    for sys in SystemKind::ALL {
        let sysrows: Vec<_> = rows.iter().filter(|r| r.system == sys).collect();
        let dig = sysrows.iter().find(|r| r.label.contains("DIG")).unwrap();
        let tight = sysrows.iter().find(|r| r.label.contains("case1")).unwrap();
        let loose = sysrows.iter().find(|r| r.label.contains("loose")).unwrap();
        println!(
            "[{}] loose vs DIG: {:.1}x speedup (paper 4.1x); loose vs tight: {:.1}x slowdown (paper ~3.1x)",
            sys.name(),
            dig.time_s / loose.time_s,
            loose.time_s / tight.time_s,
        );
    }
}
