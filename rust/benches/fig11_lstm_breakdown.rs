//! Bench: regenerate Fig. 11 — LSTM analog sub-ROI breakdown on the
//! high-power system. Paper findings to reproduce in shape: cell
//! dequeue + activation dominate (up to ~81.8%), gate combination next
//! (up to ~14.9%); activations alone ~70% of the dequeue+activation
//! share.

use alpine::coordinator::experiments;
use alpine::report;
use alpine::stats::RoiKind;

fn main() {
    let rows = experiments::fig11_lstm_breakdown(experiments::LSTM_INFERENCES).unwrap();
    report::roi_table("Fig. 11 — LSTM sub-ROI breakdown (high-power)", &rows).print();

    for r in &rows {
        let deq_act =
            r.roi.fraction(RoiKind::AnalogDequeue) + r.roi.fraction(RoiKind::Activation);
        let combine = r.roi.fraction(RoiKind::GateCombine);
        println!(
            "{}: dequeue+activation {:.1}%, gate combine {:.1}%",
            r.label,
            100.0 * deq_act,
            100.0 * combine
        );
    }
}
