//! Bench: regenerate Fig. 8 — MLP run-time percentage per sub-ROI
//! (input load, analog queue/process/dequeue, activation, writeback,
//! digital MVM) for the digital reference and analog cases 1, 3, 4.

use alpine::coordinator::experiments;
use alpine::report;
use alpine::stats::RoiKind;

fn main() {
    let rows = experiments::fig8_mlp_breakdown(experiments::MLP_INFERENCES).unwrap();
    report::roi_table("Fig. 8 — MLP sub-ROI run-time breakdown", &rows).print();

    // The paper's qualitative checks, printed for eyeballing:
    for r in &rows {
        if r.label.contains("ANA") {
            let q = r.roi.fraction(RoiKind::AnalogQueue) + r.roi.fraction(RoiKind::AnalogDequeue);
            let p = r.roi.fraction(RoiKind::AnalogProcess);
            println!(
                "{} [{}]: queue+dequeue {:.1}% of ROI, process {:.1}% (paper: queue/dequeue dominate, process minor)",
                r.label,
                r.system.name(),
                100.0 * q,
                100.0 * p
            );
        }
    }
}
