//! Bench: regenerate Fig. 14 — per-core idle% and IPC for CNN-S on the
//! high-power system, DIG vs ANA. Paper shape: conv1 utilization similar
//! in both (input-load bound); conv2/3 idle cycles drop up to 4x with
//! AIMC; dense-layer cores idle the most.

use alpine::coordinator::experiments;
use alpine::report;

fn main() {
    let rows = experiments::fig14_cnn_utilization(experiments::CNN_INFERENCES).unwrap();
    report::utilization_table(
        "Fig. 14 — CNN-S per-core utilization (high-power; cores 0-4 = conv1-5, 5-7 = dense1-3)",
        &rows,
    )
    .print();
}
