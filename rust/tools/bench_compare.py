#!/usr/bin/env python3
"""Compare benchkit JSON reports against committed baselines.

The benchkit format (util/benchkit.rs::json_report) is a flat object:

    { "bench/name": {"mean_ns": .., "min_ns": .., "stddev_ns": .., "iters": N}, .. }

For every current report, the baseline of the same file name is looked
up in --baseline. A tracked metric regresses when

    (current - baseline) / baseline > threshold     (default 10%)

on the chosen metric (default min_ns — the least noisy of the three on
shared CI runners). Sub-floor benches (default < 50 us) are reported but
never fail the build: at that scale runner jitter exceeds any real
signal. New benches (no baseline entry) and removed ones are informational.

Exit status: 1 if any metric regressed, else 0. Missing baseline files
are the bootstrap case: the script reports them and exits 0 so the first
toolchain run can go green and commit its artifact as the baseline (see
BENCH_baseline/README.md for the update workflow).
"""

import argparse
import json
import os
import sys


def flatten_serving(report):
    """Flatten a serve-bench report (BENCH_serving.json) into benchkit
    shape so the same regression gate covers serving latency.

    Tracked metrics, all bigger-is-worse in ns:
      serving/p99_at_{load}x      tail latency at each offered-load point
      serving/ns_per_req_at_saturation   1e9 / measured saturation rps
    """
    flat = {}
    for p in report.get("points", []):
        key = f"serving/p99_at_{p['load_frac']:.2f}x"
        ns = p["p99_ps"] / 1000.0
        flat[key] = {"mean_ns": ns, "min_ns": ns, "stddev_ns": 0.0, "iters": 1}
    sat = report.get("saturation_rps_measured", 0.0)
    if sat > 0:
        ns = 1e9 / sat
        flat["serving/ns_per_req_at_saturation"] = {
            "mean_ns": ns, "min_ns": ns, "stddev_ns": 0.0, "iters": 1,
        }
    return flat


def flatten_reliability(report):
    """Flatten a reliability sweep (BENCH_reliability.json) into benchkit
    shape so the regression gate covers the cost of staying accurate.

    Tracked metrics, all bigger-is-worse in ns:
      reliability/{policy}_h{horizon}_ns_per_req   1e9 / achieved rps
      reliability/{policy}_h{horizon}_downtime_ns  total reprogram downtime
    Accuracy outcomes (slo_ok, violation counts, proxy timeline) are
    correctness, not performance — the rust test suite gates those.
    """
    flat = {}
    for pol in report.get("policies", []):
        for c in pol.get("cells", []):
            tag = f"reliability/{pol['policy']}_h{c['horizon_s']:.0e}"
            rps = c.get("achieved_rps", 0.0)
            if rps > 0:
                ns = 1e9 / rps
                flat[f"{tag}_ns_per_req"] = {
                    "mean_ns": ns, "min_ns": ns, "stddev_ns": 0.0, "iters": 1,
                }
            ns = c.get("recal_downtime_ps", 0) / 1000.0
            flat[f"{tag}_downtime_ns"] = {
                "mean_ns": ns, "min_ns": ns, "stddev_ns": 0.0, "iters": 1,
            }
    return flat


def load(path):
    with open(path) as f:
        data = json.load(f)
    # Scenario reports carry structured curves instead of flat benchkit
    # entries; normalize them so one comparison loop handles all shapes.
    if isinstance(data, dict) and data.get("scenario") == "reliability":
        return flatten_reliability(data)
    if isinstance(data, dict) and "points" in data:
        return flatten_serving(data)
    return data


def fmt_ns(ns):
    if ns >= 1e9:
        return f"{ns / 1e9:.3f} s"
    if ns >= 1e6:
        return f"{ns / 1e6:.3f} ms"
    if ns >= 1e3:
        return f"{ns / 1e3:.3f} us"
    return f"{ns:.0f} ns"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="directory holding baseline BENCH_*.json files")
    ap.add_argument("--current", nargs="+", required=True, help="freshly generated BENCH_*.json files")
    ap.add_argument("--threshold", type=float, default=0.10, help="relative regression that fails the build")
    ap.add_argument("--metric", default="min_ns", choices=["min_ns", "mean_ns"])
    ap.add_argument("--noise-floor-ns", type=float, default=50_000.0,
                    help="benches faster than this never fail (runner jitter dominates)")
    ap.add_argument("--out", default=None, help="write the comparison table as markdown here")
    args = ap.parse_args()

    lines = ["| bench | baseline | current | delta | status |",
             "|---|---|---|---|---|"]
    regressions = []
    bootstrap = []

    for cur_path in args.current:
        name = os.path.basename(cur_path)
        cur = load(cur_path)
        base_path = os.path.join(args.baseline, name)
        if not os.path.exists(base_path):
            bootstrap.append(name)
            for bench in sorted(cur):
                lines.append(f"| {bench} | — | {fmt_ns(cur[bench][args.metric])} | — | no baseline |")
            continue
        base = load(base_path)
        for bench in sorted(set(cur) | set(base)):
            if bench not in base:
                lines.append(f"| {bench} | — | {fmt_ns(cur[bench][args.metric])} | — | new |")
                continue
            if bench not in cur:
                lines.append(f"| {bench} | {fmt_ns(base[bench][args.metric])} | — | — | removed |")
                continue
            b, c = base[bench][args.metric], cur[bench][args.metric]
            delta = (c - b) / b if b > 0 else 0.0
            if delta > args.threshold and c >= args.noise_floor_ns:
                status = f"REGRESSION (> {args.threshold:.0%})"
                regressions.append((bench, b, c, delta))
            elif delta > args.threshold:
                status = "noisy (sub-floor, ignored)"
            elif delta < -args.threshold:
                status = "improved"
            else:
                status = "ok"
            lines.append(f"| {bench} | {fmt_ns(b)} | {fmt_ns(c)} | {delta:+.1%} | {status} |")

    table = "\n".join(lines)
    print(table)
    if args.out:
        with open(args.out, "w") as f:
            f.write(f"# bench-regression ({args.metric}, threshold {args.threshold:.0%})\n\n")
            f.write(table + "\n")
            if bootstrap:
                f.write("\nNo baseline for: " + ", ".join(bootstrap)
                        + " — commit the current reports to BENCH_baseline/ to arm the gate.\n")

    if bootstrap:
        print(f"\nbootstrap: no baseline for {', '.join(bootstrap)}; "
              "commit the generated reports to BENCH_baseline/ to arm the gate.")
    if regressions:
        print(f"\nFAIL: {len(regressions)} metric(s) regressed more than {args.threshold:.0%}:")
        for bench, b, c, delta in regressions:
            print(f"  {bench}: {fmt_ns(b)} -> {fmt_ns(c)} ({delta:+.1%})")
        sys.exit(1)
    print("\nbench-regression: OK")


if __name__ == "__main__":
    main()
