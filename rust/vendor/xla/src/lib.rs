//! Build-time stub of the `xla` (PJRT) bindings.
//!
//! The offline vendor set does not carry the real `xla` crate or the
//! `xla_extension` shared library, so this path dependency provides the
//! exact API surface `alpine::runtime` consumes. The data-plumbing types
//! ([`Literal`], [`ArrayShape`]) are fully functional (they back the
//! manifest/literal round-trip tests); the execution-plane entry points
//! ([`HloModuleProto::from_text_file`], [`PjRtLoadedExecutable::execute`])
//! return a clear `Error` so callers degrade to "PJRT unavailable"
//! instead of failing to link. Swapping the real bindings back in is a
//! one-line Cargo.toml change — no source edits.

use std::fmt;

/// Error type mirroring `xla::Error`: implements `std::error::Error`
/// so `?` converts it into `anyhow::Error` at call sites.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} unavailable: built against the offline xla stub \
         (vendor/xla); install the real xla_extension bindings to enable PJRT"
    ))
}

/// Element types a [`Literal`] can be read back as. Only `f32` is used
/// by this repository's artifacts.
pub trait NativeType: Copy {
    fn from_f32(v: f32) -> Self;
    fn to_f32(self) -> f32;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
    fn to_f32(self) -> f32 {
        self
    }
}

/// A shaped f32 tensor (functional: backs the manifest round-trip).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a flat slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let elems: i64 = dims.iter().product();
        if elems < 0 || elems as usize != self.data.len() {
            return Err(Error(format!(
                "reshape to {:?} ({} elems) mismatches literal of {} elems",
                dims,
                elems,
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Destructure a tuple result. The stub cannot execute computations,
    /// so no tuple literal can exist to destructure.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("tuple literal"))
    }
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (stub: parsing requires the real bindings).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HLO text parsing"))
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT CPU client (stub: constructible so `Runtime::new` succeeds and
/// artifact-less environments can probe-and-skip gracefully).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PJRT compilation"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PJRT execution"))
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn execution_plane_reports_stub() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "stub-cpu");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(client.compile(&XlaComputation).is_err());
        let err = PjRtLoadedExecutable.execute::<Literal>(&[]).unwrap_err();
        assert!(format!("{err}").contains("stub"));
    }
}
