//! Minimal, std-only reimplementation of the `anyhow` API surface used by
//! this repository. The offline vendor set has no registry crates, so
//! this path dependency supplies exactly what the code needs:
//!
//! * [`Error`] — an opaque error value carrying a context chain
//! * [`Result`] — `Result<T, Error>` with a defaulted error type
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`
//! * `anyhow!`, `bail!`, `ensure!` macros
//!
//! Semantics mirror the real crate where it matters here: `Display`
//! shows the outermost message, `{:#}` shows the whole chain joined by
//! `": "`, and `Debug` shows the chain with a `Caused by` trailer. The
//! real crate can be dropped back in without source changes.

use std::fmt;

/// An error with an ordered context chain; `chain[0]` is the outermost
/// (most recently attached) message.
pub struct Error {
    chain: Vec<String>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message.
    pub fn root_cause_message(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for (i, cause) in rest.iter().enumerate() {
                        write!(f, "\n    {i}: {cause}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` legal.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Attach context to a fallible value.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing model line").unwrap_err();
        assert_eq!(format!("{e}"), "missing model line");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<u32, std::io::Error> = Ok(7);
        let mut called = false;
        let r = ok.with_context(|| {
            called = true;
            "ctx"
        });
        assert_eq!(r.unwrap(), 7);
        assert!(!called, "context closure must not run on Ok");
    }

    #[test]
    fn macros_work() {
        fn inner(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert_eq!(inner(true).unwrap(), 1);
        let e = inner(false).unwrap_err();
        assert_eq!(format!("{e}"), "flag was false");
        let direct = anyhow!("x = {}", 42);
        assert_eq!(format!("{direct}"), "x = 42");
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("x").is_err());
    }

    #[test]
    fn debug_has_caused_by() {
        let e: Error = Err::<(), _>(io_err()).context("outer").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by"));
    }
}
